//! Online ingest: the trained tail advances while the system serves
//! and forgets (the continual-learning/unlearning interplay the source
//! paper's train-then-serve lifecycle leaves open; SoK 2506.09227
//! § ongoing-training).
//!
//! New user documents append as durable **doc segments** under
//! `<run_dir>/ingest/`, and bounded **train-increments** extend the run's
//! WAL with fresh segments — both commit through one JSON-lines
//! **interleave log** (`interleave.log`) that totally orders every
//! ingest, train-increment, forget and launder decision.  The whole
//! serving history then replays as ONE pinned program: the WAL + IdMap
//! still fully determine the microbatch graph (replay never calls the
//! sampler), so `forget(u)` after K interleaved rounds is bit-identical
//! to an oracle that trained the final corpus with u's closure masked
//! from step 0 (Thm. A.1 applied inductively across increments — proven
//! in `tests/ingest_equality.rs`).
//!
//! Durability contract (swept in crash-matrix sequence 7):
//! - A doc segment is committed by its `ingest` log entry; a train-
//!   increment's WAL segments are committed by its `train` entry.  The
//!   entry append + fsync is THE commit point of each round.
//! - [`recover`] deletes WAL segments past the last committed count and
//!   doc segments without a committed entry — a torn round is rolled
//!   back wholesale, so a torn ingest is *never trained on*, and a
//!   plain retry of the round (same `round` key) converges to the
//!   never-crashed bytes because [`increment_schedule`] is a pure
//!   function of `(corpus_len, run_seed, from_step, n_steps)`.
//! - The grown IdMap is staged under `ingest/idmap.stage/` and promoted
//!   only after the commit point; a leftover stage is promoted or
//!   discarded by [`recover`] depending on whether its `train` entry
//!   committed.  The live map is never rewritten pre-commit, so no
//!   crash can strand the run behind IdMap's fail-closed checksum.
//! - Increments checkpoint AFTER the commit point (never mid-run), so
//!   no stored checkpoint can embed influence from a WAL tail that
//!   recovery would truncate.  The one crash window — committed entry,
//!   missing checkpoint — is healed at [`reopen`] by replaying the
//!   clean tail.
//!
//! Ordering contract vs the jobs WAL: the jobs WAL orders *requests*
//! (durable before ack); the interleave log orders *state mutations*.
//! The server's drain loop executes jobs in submission order with
//! ingest/launder acting as barriers between coalesced forget groups,
//! and records each executed mutation here — so the interleave log is
//! the replayable serialization of what the jobs WAL admitted.

use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};

use crate::checkpoint::TrainState;
use crate::config::RunConfig;
use crate::controller::{IngestStatus, UnlearnSystem};
use crate::data::corpus::{Corpus, Sample, SampleKind};
use crate::data::sampler::{DeterministicSampler, Microbatch};
use crate::data::tokenizer::ByteTokenizer;
use crate::harness::TrainedSystem;
use crate::neardup::simhash::simhash_tokens;
use crate::runtime::Runtime;
use crate::trainer::SegmentStage;
use crate::util::faultfs;
use crate::util::hashing::sha256_hex;
use crate::util::json::{parse, Json};
use crate::util::rng::philox_u64;
use crate::wal::{segment_count, WalRecord, WalWriter};

/// Philox counter domain separating increment schedules from the base
/// run's sampler and every other derived seed in the tree.
const INGEST_SEED_DOMAIN: u64 = 0x1A65_E570;

/// The four files one `IdMap::save` writes (entries, checksum, retired
/// sidecar, sidecar checksum) — the unit the staged-promote protocol
/// moves together.
const IDMAP_FILES: [&str; 4] =
    ["ids.map", "ids.map.sum", "ids.map.retired", "ids.map.retired.sum"];

/// One document arriving through the ingest plane.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IngestDoc {
    pub user: u32,
    pub text: String,
}

/// A bounded tail advance: `n_steps` logical optimizer steps starting
/// at `from_step` (the current end of the logged program).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TrainStep {
    pub from_step: u32,
    pub n_steps: u32,
}

/// One committed decision of the interleave log, in file order.
#[derive(Debug, Clone, PartialEq)]
pub enum InterleaveEntry {
    /// First entry ever: the base run's sealed WAL segment count and
    /// corpus length, recorded BEFORE any ingest mutates the run dir —
    /// recovery needs the committed baseline even if the very first
    /// round crashes pre-commit.
    Open { wal_segments: u64, corpus_len: u64 },
    /// Doc segment `docs-{seq:06}.seg` committed: `docs` documents with
    /// dense sample ids starting at `base_id`.
    Ingest { seq: u64, round: u64, docs: u64, base_id: u64 },
    /// Train-increment committed: the WAL now has `wal_segments`
    /// segments and its schedule was drawn over `corpus_len` samples.
    Train {
        seq: u64,
        round: u64,
        from_step: u32,
        n_steps: u32,
        corpus_len: u64,
        wal_segments: u64,
        applied_updates: u64,
    },
    /// A forget batch executed between increments (ordering record;
    /// the signed manifest carries the full closure detail).
    Forget { seq: u64, request: String, closure: u64 },
    /// A laundering pass executed between increments.
    Launder { seq: u64, key: String },
}

impl InterleaveEntry {
    /// Commit sequence number (`None` for the leading `open` entry).
    pub fn seq(&self) -> Option<u64> {
        match self {
            InterleaveEntry::Open { .. } => None,
            InterleaveEntry::Ingest { seq, .. }
            | InterleaveEntry::Train { seq, .. }
            | InterleaveEntry::Forget { seq, .. }
            | InterleaveEntry::Launder { seq, .. } => Some(*seq),
        }
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self {
            InterleaveEntry::Open {
                wal_segments,
                corpus_len,
            } => {
                j.set("entry", "open")
                    .set("wal_segments", *wal_segments)
                    .set("corpus_len", *corpus_len);
            }
            InterleaveEntry::Ingest {
                seq,
                round,
                docs,
                base_id,
            } => {
                j.set("entry", "ingest")
                    .set("seq", *seq)
                    .set("round", *round)
                    .set("docs", *docs)
                    .set("base_id", *base_id);
            }
            InterleaveEntry::Train {
                seq,
                round,
                from_step,
                n_steps,
                corpus_len,
                wal_segments,
                applied_updates,
            } => {
                j.set("entry", "train")
                    .set("seq", *seq)
                    .set("round", *round)
                    .set("from_step", *from_step as u64)
                    .set("n_steps", *n_steps as u64)
                    .set("corpus_len", *corpus_len)
                    .set("wal_segments", *wal_segments)
                    .set("applied_updates", *applied_updates);
            }
            InterleaveEntry::Forget {
                seq,
                request,
                closure,
            } => {
                j.set("entry", "forget")
                    .set("seq", *seq)
                    .set("request", request.as_str())
                    .set("closure", *closure);
            }
            InterleaveEntry::Launder { seq, key } => {
                j.set("entry", "launder")
                    .set("seq", *seq)
                    .set("key", key.as_str());
            }
        }
        j
    }

    fn from_json(j: &Json) -> anyhow::Result<InterleaveEntry> {
        let kind = j
            .get("entry")
            .and_then(|e| e.as_str())
            .ok_or_else(|| anyhow::anyhow!("interleave entry without kind"))?;
        let need = |key: &str| -> anyhow::Result<u64> {
            j.get(key).and_then(|v| v.as_u64()).ok_or_else(|| {
                anyhow::anyhow!("interleave {kind} entry missing {key}")
            })
        };
        Ok(match kind {
            "open" => InterleaveEntry::Open {
                wal_segments: need("wal_segments")?,
                corpus_len: need("corpus_len")?,
            },
            "ingest" => InterleaveEntry::Ingest {
                seq: need("seq")?,
                round: need("round")?,
                docs: need("docs")?,
                base_id: need("base_id")?,
            },
            "train" => InterleaveEntry::Train {
                seq: need("seq")?,
                round: need("round")?,
                from_step: need("from_step")? as u32,
                n_steps: need("n_steps")? as u32,
                corpus_len: need("corpus_len")?,
                wal_segments: need("wal_segments")?,
                applied_updates: need("applied_updates")?,
            },
            "forget" => InterleaveEntry::Forget {
                seq: need("seq")?,
                request: j
                    .get("request")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
                closure: need("closure")?,
            },
            "launder" => InterleaveEntry::Launder {
                seq: need("seq")?,
                key: j
                    .get("key")
                    .and_then(|v| v.as_str())
                    .unwrap_or_default()
                    .to_string(),
            },
            other => anyhow::bail!("unknown interleave entry kind {other:?}"),
        })
    }
}

/// The durable interleave log of one run's online-serving history.
pub struct IngestLog {
    run_dir: PathBuf,
    dir: PathBuf,
    log_path: PathBuf,
    pub entries: Vec<InterleaveEntry>,
    next_seq: u64,
}

impl IngestLog {
    fn paths(run_dir: &Path) -> (PathBuf, PathBuf) {
        let dir = run_dir.join("ingest");
        let log_path = dir.join("interleave.log");
        (dir, log_path)
    }

    /// Parse `interleave.log`, returning the entries plus the byte
    /// length of the committed prefix.  A torn FINAL line (the
    /// crash-mid-append window) is dropped; interior corruption fails
    /// closed, mirroring the jobs-WAL recovery posture.
    fn parse_log(
        text: &str,
    ) -> anyhow::Result<(Vec<InterleaveEntry>, usize)> {
        let segs: Vec<&str> = text.split_inclusive('\n').collect();
        let mut entries = Vec::new();
        let mut clean_len = 0usize;
        let mut pos = 0usize;
        for (i, seg) in segs.iter().enumerate() {
            pos += seg.len();
            let line = seg.trim();
            if line.is_empty() {
                clean_len = pos;
                continue;
            }
            if !seg.ends_with('\n') {
                // the commit point is the durable append of the FULL
                // newline-terminated line: a tail missing its newline
                // never committed, even if its JSON happens to parse —
                // and it must be scrubbed before any future append
                break;
            }
            let parsed = parse(line)
                .map_err(|e| anyhow::anyhow!("bad interleave line: {e}"))
                .and_then(|j| InterleaveEntry::from_json(&j));
            match parsed {
                Ok(e) => {
                    entries.push(e);
                    clean_len = pos;
                }
                Err(err) if i == segs.len() - 1 => {
                    // torn tail: the entry never committed
                    let _ = err;
                    break;
                }
                Err(err) => {
                    anyhow::bail!(
                        "interleave.log corrupt at interior line {}: {err}",
                        i + 1
                    );
                }
            }
        }
        // structural validation: exactly one leading `open`, seqs
        // strictly increasing — anything else is not a torn tail but a
        // mangled history, and serving over it would be guesswork
        let mut last_seq: Option<u64> = None;
        for (i, e) in entries.iter().enumerate() {
            match (i, e) {
                (0, InterleaveEntry::Open { .. }) => {}
                (0, _) => anyhow::bail!(
                    "interleave.log does not start with an open entry"
                ),
                (_, InterleaveEntry::Open { .. }) => {
                    anyhow::bail!("interleave.log has a second open entry")
                }
                _ => {}
            }
            if let Some(seq) = e.seq() {
                anyhow::ensure!(
                    last_seq.map_or(true, |p| seq > p),
                    "interleave.log seq not strictly increasing at {seq}"
                );
                last_seq = Some(seq);
            }
        }
        Ok((entries, clean_len))
    }

    /// Open an existing log (`Ok(None)` when the run has never
    /// ingested).  A torn tail is scrubbed durably here — a later
    /// append must never land after partial bytes, which would weld
    /// two lines into unparseable interior corruption.
    pub fn open(run_dir: &Path) -> anyhow::Result<Option<IngestLog>> {
        let (dir, log_path) = Self::paths(run_dir);
        if !log_path.exists() {
            return Ok(None);
        }
        let text = fs::read_to_string(&log_path)?;
        let (entries, clean_len) = Self::parse_log(&text)?;
        if clean_len < text.len() {
            // tmp + rename: committed bytes are never rewritten in
            // place, so a crash mid-scrub leaves old-or-new, both of
            // which reopen to the same committed prefix
            crate::checkpoint::write_atomic(&log_path, &text[..clean_len])?;
        }
        if entries.is_empty() {
            // only a torn open line ever made it to disk: nothing was
            // committed, treat as never-attached
            return Ok(None);
        }
        let next_seq =
            entries.iter().filter_map(|e| e.seq()).max().map_or(0, |s| s + 1);
        Ok(Some(IngestLog {
            run_dir: run_dir.to_path_buf(),
            dir,
            log_path,
            entries,
            next_seq,
        }))
    }

    /// Attach to a run: open the existing log, or create one whose
    /// `open` entry freezes the base run's committed WAL segment count
    /// and corpus length BEFORE any ingest mutation.
    pub fn attach(
        run_dir: &Path,
        corpus_len: usize,
    ) -> anyhow::Result<IngestLog> {
        if let Some(log) = Self::open(run_dir)? {
            return Ok(log);
        }
        let (dir, log_path) = Self::paths(run_dir);
        fs::create_dir_all(&dir)?;
        let entry = InterleaveEntry::Open {
            wal_segments: segment_count(&run_dir.join("wal"))?,
            corpus_len: corpus_len as u64,
        };
        // a torn attach leaves an unparseable (or absent) line that the
        // next attach overwrites — no WAL mutation precedes the open
        // entry, so dropping it loses nothing
        faultfs::write(
            &log_path,
            format!("{}\n", entry.to_json().encode()).as_bytes(),
        )?;
        faultfs::fsync(&log_path)?;
        Ok(IngestLog {
            run_dir: run_dir.to_path_buf(),
            dir,
            log_path,
            entries: vec![entry],
            next_seq: 0,
        })
    }

    /// Append one entry durably (append + fsync = the commit point).
    fn commit(&mut self, entry: InterleaveEntry) -> anyhow::Result<()> {
        faultfs::append(
            &self.log_path,
            format!("{}\n", entry.to_json().encode()).as_bytes(),
        )?;
        faultfs::fsync(&self.log_path)?;
        if let Some(seq) = entry.seq() {
            self.next_seq = seq + 1;
        }
        self.entries.push(entry);
        Ok(())
    }

    fn doc_seg_path(&self, seq: u64) -> PathBuf {
        self.dir.join(format!("docs-{seq:06}.seg"))
    }

    /// WAL segment count as of the last committed entry that moved it.
    pub fn committed_wal_segments(&self) -> u64 {
        let mut committed = 0;
        for e in &self.entries {
            match e {
                InterleaveEntry::Open { wal_segments, .. }
                | InterleaveEntry::Train { wal_segments, .. } => {
                    committed = *wal_segments;
                }
                _ => {}
            }
        }
        committed
    }

    /// Corpus length covered by the latest committed train-increment
    /// (the base corpus length before any increment ran).
    pub fn covered_len(&self) -> u64 {
        let mut covered = 0;
        for e in &self.entries {
            match e {
                InterleaveEntry::Open { corpus_len, .. }
                | InterleaveEntry::Train { corpus_len, .. } => {
                    covered = *corpus_len;
                }
                _ => {}
            }
        }
        covered
    }

    /// Total committed ingest documents.
    pub fn ingested_docs(&self) -> u64 {
        self.entries
            .iter()
            .map(|e| match e {
                InterleaveEntry::Ingest { docs, .. } => *docs,
                _ => 0,
            })
            .sum()
    }

    pub fn has_ingest_round(&self, round: u64) -> bool {
        self.entries.iter().any(
            |e| matches!(e, InterleaveEntry::Ingest { round: r, .. } if *r == round),
        )
    }

    pub fn has_train_round(&self, round: u64) -> bool {
        self.entries.iter().any(
            |e| matches!(e, InterleaveEntry::Train { round: r, .. } if *r == round),
        )
    }

    /// Record an executed forget batch (ordering record, post-commit).
    pub fn record_forget(
        &mut self,
        request: &str,
        closure: usize,
    ) -> anyhow::Result<()> {
        let seq = self.next_seq;
        self.commit(InterleaveEntry::Forget {
            seq,
            request: request.to_string(),
            closure: closure as u64,
        })
    }

    /// Record an executed laundering pass (ordering record).
    pub fn record_launder(&mut self, key: &str) -> anyhow::Result<()> {
        let seq = self.next_seq;
        self.commit(InterleaveEntry::Launder {
            seq,
            key: key.to_string(),
        })
    }

    /// Read back every committed doc segment in commit order, verifying
    /// each against its checksum sidecar (fail closed: a doc segment
    /// that no longer matches what was committed must not re-enter the
    /// corpus under the committed ids).
    pub fn committed_docs(&self) -> anyhow::Result<Vec<(u64, Vec<IngestDoc>)>> {
        let mut out = Vec::new();
        for e in &self.entries {
            let InterleaveEntry::Ingest {
                seq,
                docs,
                base_id,
                ..
            } = e
            else {
                continue;
            };
            let path = self.doc_seg_path(*seq);
            let bytes = fs::read(&path)?;
            let sum_text =
                fs::read_to_string(path.with_extension("seg.sum"))?;
            let sum = parse(&sum_text)
                .map_err(|e| anyhow::anyhow!("bad doc seg sum: {e}"))?;
            let expect = sum
                .get("sha256")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("doc seg sum missing sha256"))?;
            anyhow::ensure!(
                sha256_hex(&bytes) == expect,
                "doc segment {} fails its committed checksum",
                path.display()
            );
            let text = String::from_utf8(bytes)?;
            let mut parsed = Vec::new();
            for line in text.lines().filter(|l| !l.trim().is_empty()) {
                let j = parse(line)
                    .map_err(|e| anyhow::anyhow!("bad doc line: {e}"))?;
                parsed.push(IngestDoc {
                    user: j
                        .get("user")
                        .and_then(|v| v.as_u64())
                        .ok_or_else(|| anyhow::anyhow!("doc without user"))?
                        as u32,
                    text: j
                        .get("text")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow::anyhow!("doc without text"))?
                        .to_string(),
                });
            }
            anyhow::ensure!(
                parsed.len() as u64 == *docs,
                "doc segment {} has {} docs, entry committed {}",
                path.display(),
                parsed.len(),
                docs
            );
            out.push((*base_id, parsed));
        }
        Ok(out)
    }

    /// Durably commit one batch of docs: segment + checksum sidecar,
    /// then the `ingest` entry (the commit point).  Returns the first
    /// assigned sample id.
    fn append_docs(
        &mut self,
        round: u64,
        base_id: u64,
        docs: &[IngestDoc],
    ) -> anyhow::Result<u64> {
        let seq = self.next_seq;
        let mut body = String::new();
        for d in docs {
            let mut j = Json::obj();
            j.set("user", d.user).set("text", d.text.as_str());
            body.push_str(&j.encode());
            body.push('\n');
        }
        let path = self.doc_seg_path(seq);
        faultfs::write(&path, body.as_bytes())?;
        let mut sum = Json::obj();
        sum.set("segment", seq)
            .set("docs", docs.len())
            .set("sha256", sha256_hex(body.as_bytes()));
        faultfs::write(&path.with_extension("seg.sum"), sum.pretty().as_bytes())?;
        self.commit(InterleaveEntry::Ingest {
            seq,
            round,
            docs: docs.len() as u64,
            base_id,
        })?;
        Ok(base_id)
    }
}

/// What [`recover`] rolled back.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    pub wal_segments_removed: u64,
    pub doc_segments_removed: u64,
}

/// Copy a staged IdMap over the live one and drop the stage.  Copies
/// (not renames) keep the stage intact as the source of truth until
/// every file has landed, so a crash mid-promote re-promotes
/// idempotently from [`recover`].
fn promote_idmap_stage(run_dir: &Path) -> anyhow::Result<()> {
    let stage = run_dir.join("ingest").join("idmap.stage");
    if !stage.exists() {
        return Ok(());
    }
    for name in IDMAP_FILES {
        let from = stage.join(name);
        if from.exists() {
            let to = run_dir.join(name);
            faultfs::copy(&from, &to)?;
            faultfs::fsync(&to)?;
        }
    }
    faultfs::remove_dir_all(&stage)?;
    Ok(())
}

/// Roll back every uncommitted artifact of a torn round: WAL segments
/// past the last committed count and doc segments without an `ingest`
/// entry.  Idempotent, and mandatory before reopening the system — the
/// WAL reader reads every segment present, and a retry that appended
/// after an un-truncated torn increment would duplicate opt_steps and
/// trip replay's monotone-order check.
pub fn recover(
    run_dir: &Path,
    log: &IngestLog,
) -> anyhow::Result<RecoveryReport> {
    let mut report = RecoveryReport::default();
    // Staged IdMap from the last increment: promote iff its `train`
    // entry committed (the stage then carries the registrations the
    // committed WAL tail needs), else discard — the live map was never
    // touched pre-commit, so discarding loses nothing.
    let stage = log.dir.join("idmap.stage");
    if stage.exists() {
        let committed = fs::read_to_string(stage.join("round.json"))
            .ok()
            .and_then(|t| parse(&t).ok())
            .and_then(|j| j.get("round").and_then(|v| v.as_u64()))
            .is_some_and(|r| log.has_train_round(r));
        if committed {
            promote_idmap_stage(run_dir)?;
        } else {
            faultfs::remove_dir_all(&stage)?;
        }
    }
    let wal_dir = run_dir.join("wal");
    let committed = log.committed_wal_segments();
    for idx in committed..segment_count(&wal_dir)? {
        let seg = wal_dir.join(format!("wal-{idx:06}.seg"));
        faultfs::remove_file(&seg)?;
        let sum = seg.with_extension("seg.sum");
        if sum.exists() {
            faultfs::remove_file(&sum)?;
        }
        report.wal_segments_removed += 1;
    }
    let committed_docs: HashSet<u64> = log
        .entries
        .iter()
        .filter_map(|e| match e {
            InterleaveEntry::Ingest { seq, .. } => Some(*seq),
            _ => None,
        })
        .collect();
    let mut stray: Vec<PathBuf> = Vec::new();
    for entry in fs::read_dir(&log.dir)? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        let Some(stem) = name.strip_prefix("docs-") else { continue };
        let Some(seq) = stem
            .strip_suffix(".seg")
            .or_else(|| stem.strip_suffix(".seg.sum"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        if !committed_docs.contains(&seq) {
            stray.push(path);
        }
    }
    stray.sort(); // deterministic removal order (read_dir order is not)
    for path in &stray {
        faultfs::remove_file(path)?;
        if path.extension().is_some_and(|e| e == "seg") {
            report.doc_segments_removed += 1;
        }
    }
    Ok(report)
}

/// Stable round key for an admin-plane request id (retry idempotency).
pub fn round_of(id: &str) -> u64 {
    let hex = sha256_hex(id.as_bytes());
    u64::from_str_radix(&hex[..16], 16).expect("sha256 hex")
}

/// Materialize docs as corpus samples with dense ids from `base_id` and
/// insert them into the live near-dup index — the growth that keeps
/// closure expansion, `Corpus::by_id` and the Planner's live-tail costs
/// in sync with what the WAL will reference.  Crate-visible: the fleet
/// reuses it to grow its GLOBAL routing view alongside the owning
/// shard's local corpus.
pub(crate) fn grow_corpus(
    corpus: &mut Corpus,
    ndindex: &mut crate::neardup::HammingIndex,
    base_id: u64,
    docs: &[IngestDoc],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        corpus.len() as u64 == base_id,
        "ingest base_id {base_id} does not match corpus length {}",
        corpus.len()
    );
    let tok = ByteTokenizer;
    for (i, d) in docs.iter().enumerate() {
        let id = base_id + i as u64;
        let tokens = tok.encode_fixed(&d.text, corpus.config.seq_len);
        ndindex.insert(id, simhash_tokens(&tokens));
        corpus.samples.push(Sample {
            id,
            user: d.user,
            cohort: None,
            kind: SampleKind::Normal,
            text: d.text.clone(),
            tokens,
        });
    }
    Ok(())
}

/// Append a batch of documents to the live system: durable commit
/// first, then the in-memory corpus/index growth.  Returns the first
/// assigned sample id.
pub fn ingest_docs(
    sys: &mut UnlearnSystem<'_>,
    log: &mut IngestLog,
    round: u64,
    docs: &[IngestDoc],
) -> anyhow::Result<u64> {
    anyhow::ensure!(!docs.is_empty(), "ingest batch is empty");
    anyhow::ensure!(
        !sys.ingest.in_flight,
        "a train-increment is in flight (or torn and unrecovered)"
    );
    anyhow::ensure!(
        sys.cfg.run_dir == log.run_dir,
        "interleave log belongs to a different run dir"
    );
    let base_id = sys.corpus.len() as u64;
    log.append_docs(round, base_id, docs)?;
    grow_corpus(&mut sys.corpus, &mut sys.ndindex, base_id, docs)?;
    sys.ingest.ingested_docs += docs.len() as u64;
    Ok(base_id)
}

/// The deterministic schedule of one increment: a pure function of
/// `(corpus_len, batch, accum, run_seed, from_step, n_steps)` — a retry
/// after a torn round regenerates byte-identical WAL records, which is
/// what makes recovery-by-truncation converge.  Steps are re-stamped to
/// the global step axis; seed64 stays as the sampler derived it (it is
/// logged, and replay only ever reads the logged value).
pub fn increment_schedule(
    corpus_len: usize,
    batch: usize,
    accum: usize,
    run_seed: u64,
    ts: TrainStep,
) -> Vec<Microbatch> {
    let inc_seed =
        philox_u64(run_seed, INGEST_SEED_DOMAIN ^ ts.from_step as u64);
    let mut sched =
        DeterministicSampler::new(corpus_len, batch, accum, ts.n_steps, inc_seed)
            .schedule();
    for mb in &mut sched {
        mb.step += ts.from_step;
    }
    sched
}

/// What one committed train-increment did.
#[derive(Debug, Clone)]
pub struct IncrementOutcome {
    pub step: TrainStep,
    pub records_appended: usize,
    pub updates_applied: u32,
    pub wal_segments: u64,
    pub losses: Vec<(u32, f32)>,
    pub executed: bool,
}

/// Advance the trained tail by `n_steps` logical steps over the CURRENT
/// corpus, appending fresh WAL segments, and commit through the
/// interleave log.
///
/// The increment masks `forgotten ∪ laundered ∪ retired` exactly like
/// replay's traversal — graph-preserving (the logged composition still
/// includes erased ids; their mask rows are zero), so the oracle
/// equality of `tests/ingest_equality.rs` extends across increments
/// while the live tail never trains on erased data.
///
/// Commit protocol (order is the crash-safety argument):
///  1. append records / run updates (WAL segments are uncommitted),
///  2. seal the trailing segment (`WalWriter::finish`),
///  3. STAGE the grown IdMap durably (the committed map is never
///     rewritten pre-commit; orphan hashes in the stage are harmless:
///     replay only looks up hashes present in the WAL),
///  4. append + fsync the `train` entry — THE COMMIT POINT,
///  5. promote the staged IdMap over the live one,
///  6. checkpoint the advanced state (after the commit, never before).
pub fn train_increment(
    sys: &mut UnlearnSystem<'_>,
    log: &mut IngestLog,
    round: u64,
    n_steps: u32,
) -> anyhow::Result<IncrementOutcome> {
    anyhow::ensure!(n_steps > 0, "train increment of zero steps");
    anyhow::ensure!(
        !sys.ingest.in_flight,
        "a train-increment is already in flight"
    );
    anyhow::ensure!(
        sys.cfg.run_dir == log.run_dir,
        "interleave log belongs to a different run dir"
    );
    // pins re-stamped per increment: advancing the tail under a
    // different backend/geometry would log records the pinned program
    // cannot replay — fail closed exactly like replay does
    let mut current = sys.rt.capture_pins(sys.cfg.accum);
    current.shard = sys.cfg.shard_pin.clone();
    let drift = sys.pins.verify(&current);
    anyhow::ensure!(
        drift.is_empty(),
        "pin drift — refusing to advance the tail: {drift:?}"
    );
    let from_step = sys
        .records
        .iter()
        .map(|r| r.opt_step + 1)
        .max()
        .unwrap_or(0);
    anyhow::ensure!(
        sys.state.logical_step == from_step,
        "serving state at step {} but the WAL ends at {from_step} — \
         reopen/recover before advancing the tail",
        sys.state.logical_step
    );
    let ts = TrainStep { from_step, n_steps };
    sys.ingest.in_flight = true; // cleared only on commit (or recovery)

    let rt = sys.rt;
    let man = &rt.manifest;
    let corpus_len = sys.corpus.len();
    let schedule = increment_schedule(
        corpus_len,
        man.batch,
        sys.cfg.accum,
        sys.cfg.run_seed,
        ts,
    );
    // the same mask replay's traversal applies: explicit sets plus the
    // IdMap's retired ids (laundered-set compaction)
    let mut mask: HashSet<u64> =
        sys.forgotten.union(&sys.laundered).copied().collect();
    for id in 0..corpus_len as u64 {
        if sys.idmap.is_retired(id) {
            mask.insert(id);
        }
    }
    let filter = |id: u64| mask.contains(&id);

    let wal_dir = sys.cfg.run_dir.join("wal");
    let mut wal = WalWriter::append_to(
        &wal_dir,
        sys.cfg.wal_segment_records,
        sys.cfg.hmac_key.clone(),
    )?;
    let mut seg = SegmentStage::new();
    let mut appended: Vec<WalRecord> = Vec::with_capacity(schedule.len());
    let mut losses = Vec::new();
    let mut updates = 0u32;
    for mb in &schedule {
        let lr = sys.cfg.lr_at(sys.state.applied_updates);
        let hash64 = sys.idmap.register(&mb.sample_ids);
        let rec = WalRecord {
            hash64,
            seed64: mb.seed64,
            lr_bits: lr.to_bits(),
            opt_step: mb.step,
            accum_end: mb.accum_end,
            mb_len: mb.sample_ids.len() as u16,
        };
        wal.append(&rec)?;
        appended.push(rec);
        seg.stage(
            &sys.corpus,
            &mb.sample_ids,
            man.batch,
            man.seq_len,
            &filter,
            false,
            mb.seed64 as i32,
        )?;
        if mb.accum_end {
            let inputs = seg.inputs();
            if !inputs.is_empty() {
                let out = rt.grad_accumulate(&sys.state.params, &inputs)?;
                let step_before = sys.state.logical_step;
                let (p, m, v) = rt.adamw_update(
                    &sys.state.params,
                    &out.grad,
                    &sys.state.m,
                    &sys.state.v,
                    sys.state.applied_updates as i32 + 1,
                    lr,
                )?;
                let before_params =
                    std::mem::replace(&mut sys.state.params, p);
                let before_m = std::mem::replace(&mut sys.state.m, m);
                let before_v = std::mem::replace(&mut sys.state.v, v);
                sys.state.applied_updates += 1;
                sys.state.logical_step = mb.step + 1;
                updates += 1;
                sys.ring.record_parts(
                    step_before,
                    &before_params,
                    &before_m,
                    &before_v,
                    &sys.state,
                )?;
                if out.tok_count > 0.0 {
                    losses.push((mb.step, out.loss_sum / out.tok_count));
                }
            } else {
                // empty-step skip (Prop. A.5): no counter advance
                sys.state.logical_step = mb.step + 1;
            }
            seg.reset();
        }
    }
    wal.finish()?;
    // The grown IdMap is STAGED, not saved in place: rewriting the
    // committed map before the commit point could leave it failing its
    // own checksum after a crash (the entries/`.map.sum` pair cannot be
    // replaced atomically), stranding the whole run behind IdMap's
    // fail-closed load.  The stage is durable before the commit and
    // promoted after; [`recover`] promotes or discards a leftover
    // stage by whether its `train` entry committed.
    let stage = log.dir.join("idmap.stage");
    fs::create_dir_all(&stage)?;
    let mut marker = Json::obj();
    marker.set("round", round);
    faultfs::write(&stage.join("round.json"), marker.encode().as_bytes())?;
    sys.idmap.save(&stage.join("ids.map"))?;
    for name in IDMAP_FILES {
        faultfs::fsync(&stage.join(name))?;
    }
    faultfs::fsync(&stage.join("round.json"))?;
    let wal_segments = segment_count(&wal_dir)?;
    let seq = log.next_seq;
    log.commit(InterleaveEntry::Train {
        seq,
        round,
        from_step: ts.from_step,
        n_steps: ts.n_steps,
        corpus_len: corpus_len as u64,
        wal_segments,
        applied_updates: sys.state.applied_updates,
    })?;
    promote_idmap_stage(&sys.cfg.run_dir)?;
    // checkpoint strictly after the commit point; replay can now always
    // reach the committed tail end from a stored state
    sys.store.save_full(&sys.state)?;
    sys.records.extend(appended.iter().copied());
    sys.ingest.covered_len = corpus_len;
    sys.ingest.in_flight = false;
    Ok(IncrementOutcome {
        step: ts,
        records_appended: appended.len(),
        updates_applied: updates,
        wal_segments,
        losses,
        executed: true,
    })
}

/// Interleaves ingest rounds with the forget stream: one `run_round`
/// appends a doc batch and advances the tail by a bounded number of
/// steps, each half committed through the interleave log under the
/// round's idempotency key — a retry after a crash (post-[`recover`])
/// skips whatever already committed and converges bit-identically.
#[derive(Debug, Clone, Copy)]
pub struct IngestScheduler {
    /// Tail advance per round (logical steps).
    pub train_steps: u32,
}

impl IngestScheduler {
    pub fn new(train_steps: u32) -> IngestScheduler {
        IngestScheduler { train_steps }
    }

    /// One ingest round: docs then increment, each skipped if its
    /// entry already committed under `round`.
    pub fn run_round(
        &self,
        sys: &mut UnlearnSystem<'_>,
        log: &mut IngestLog,
        round: u64,
        docs: &[IngestDoc],
    ) -> anyhow::Result<IncrementOutcome> {
        if !docs.is_empty() && !log.has_ingest_round(round) {
            ingest_docs(sys, log, round, docs)?;
        }
        if self.train_steps > 0 && !log.has_train_round(round) {
            return train_increment(sys, log, round, self.train_steps);
        }
        Ok(IncrementOutcome {
            step: TrainStep {
                from_step: sys.state.logical_step,
                n_steps: 0,
            },
            records_appended: 0,
            updates_applied: 0,
            wal_segments: log.committed_wal_segments(),
            losses: Vec::new(),
            executed: false,
        })
    }
}

/// Reopen a run that has (or may have) an online-ingest history:
/// recover torn rounds, rebuild the corpus as base + committed docs,
/// open the system through the normal resume path, then heal the one
/// commit→checkpoint crash window by replaying the clean tail.
///
/// `base_corpus` must be regenerated with the run's original
/// config/seed (the same contract as `harness::open_or_build_system`).
pub fn reopen<'rt>(
    rt: &'rt Runtime,
    cfg: RunConfig,
    base_corpus: Corpus,
    estimate_fisher: bool,
) -> anyhow::Result<(TrainedSystem<'rt>, IngestLog, RecoveryReport)> {
    let run_dir = cfg.run_dir.clone();
    let mut corpus = base_corpus;
    let (existing, report) = match IngestLog::open(&run_dir)? {
        Some(log) => {
            let report = recover(&run_dir, &log)?;
            // committed docs re-enter the corpus under their committed
            // ids BEFORE the system opens: the WAL tail references them
            let mut scratch = crate::neardup::HammingIndex::new();
            for (base_id, docs) in log.committed_docs()? {
                grow_corpus(&mut corpus, &mut scratch, base_id, &docs)?;
            }
            (Some(log), report)
        }
        None => (None, RecoveryReport::default()),
    };
    let (mut ts, _resumed) = crate::harness::open_or_build_system(
        rt,
        cfg,
        corpus,
        estimate_fisher,
    )?;
    let sys = &mut ts.system;
    let log = match existing {
        Some(log) => log,
        None => IngestLog::attach(&run_dir, sys.corpus.len())?,
    };
    sys.ingest = IngestStatus {
        ingested_docs: log.ingested_docs(),
        covered_len: log.covered_len() as usize,
        in_flight: false,
    };
    // Heal the commit→checkpoint crash window: a committed increment
    // whose checkpoint never landed leaves the resume path serving a
    // state behind the WAL end (it only replays when forgotten influence
    // is pending).  Replay the clean tail — same traversal, filter =
    // laundered residue (retired ids are masked by the traversal) — and
    // re-checkpoint so the next increment starts from the tail end.
    let wal_end = sys
        .records
        .iter()
        .map(|r| r.opt_step + 1)
        .max()
        .unwrap_or(0);
    if sys.forgotten.is_empty() && sys.state.logical_step < wal_end {
        let filter = sys.laundered.clone();
        let (_, rebuilt) = crate::replay::replay_filter_from_nearest_to(
            rt,
            &sys.corpus,
            &sys.store,
            &sys.records,
            &sys.idmap,
            &filter,
            wal_end,
            Some(&sys.pins),
            &sys.replay_options(),
        )?;
        sys.state = rebuilt.state;
        sys.store.save_full(&sys.state)?;
    }
    Ok((ts, log, report))
}

/// The retain-only oracle for the full interleaved history: replay the
/// ENTIRE logged program from θ0 over the FINAL corpus with `closure`
/// masked — what "trained the final corpus minus the closure from
/// scratch" means under a preserved graph.  Shared by the equality
/// tests and benches so the proof obligation has one spelling.
pub fn oracle_state(
    sys: &UnlearnSystem<'_>,
    closure: &HashSet<u64>,
) -> anyhow::Result<TrainState> {
    let theta0 = TrainState::zeros_like(sys.rt.manifest.init_params()?);
    let out = crate::replay::replay_filter(
        sys.rt,
        &sys.corpus,
        &theta0,
        &sys.records,
        &sys.idmap,
        closure,
        Some(&sys.pins),
        &sys.replay_options(),
    )?;
    Ok(out.state)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;

    fn mk_run(tag: &str) -> PathBuf {
        let dir = tempdir(tag);
        fs::create_dir_all(dir.join("wal")).unwrap();
        dir
    }

    #[test]
    fn attach_writes_open_entry_and_reopens() {
        let run = mk_run("ingest-attach");
        let log = IngestLog::attach(&run, 42).unwrap();
        assert_eq!(
            log.entries,
            vec![InterleaveEntry::Open {
                wal_segments: 0,
                corpus_len: 42
            }]
        );
        // re-attach opens, does not re-write
        let log2 = IngestLog::attach(&run, 999).unwrap();
        assert_eq!(log2.entries, log.entries);
        assert_eq!(log2.covered_len(), 42);
    }

    #[test]
    fn docs_roundtrip_with_checksums() {
        let run = mk_run("ingest-docs");
        let mut log = IngestLog::attach(&run, 10).unwrap();
        let docs = vec![
            IngestDoc {
                user: 7,
                text: "user seven wrote about gardening".into(),
            },
            IngestDoc {
                user: 9,
                text: "user nine asked about chess".into(),
            },
        ];
        log.append_docs(1, 10, &docs).unwrap();
        let more = vec![IngestDoc {
            user: 7,
            text: "a second visit".into(),
        }];
        log.append_docs(2, 12, &more).unwrap();
        let log = IngestLog::open(&run).unwrap().unwrap();
        assert_eq!(log.ingested_docs(), 3);
        let back = log.committed_docs().unwrap();
        assert_eq!(back, vec![(10, docs), (12, more)]);
        assert!(log.has_ingest_round(1) && log.has_ingest_round(2));
        assert!(!log.has_ingest_round(3));
    }

    #[test]
    fn torn_final_line_is_dropped_interior_corruption_fails() {
        let run = mk_run("ingest-torn");
        let mut log = IngestLog::attach(&run, 5).unwrap();
        log.record_forget("req-1", 3).unwrap();
        let path = run.join("ingest/interleave.log");
        // torn tail: a partial entry never committed
        let mut text = fs::read_to_string(&path).unwrap();
        text.push_str("{\"entry\":\"tra");
        fs::write(&path, &text).unwrap();
        let mut log = IngestLog::open(&run).unwrap().unwrap();
        assert_eq!(log.entries.len(), 2);
        // the torn tail was scrubbed on open, so a post-crash append
        // cannot weld onto partial bytes and corrupt the interior
        assert!(!fs::read_to_string(&path).unwrap().contains("tra"));
        log.record_forget("req-2", 1).unwrap();
        let log = IngestLog::open(&run).unwrap().unwrap();
        assert_eq!(log.entries.len(), 3);
        // interior corruption is NOT a torn tail: fail closed
        let mangled = text.replace(
            "\"entry\":\"forget\"",
            "\"entry\":\"garbage\"",
        );
        fs::write(&path, &mangled).unwrap();
        assert!(IngestLog::open(&run).is_err());
    }

    #[test]
    fn recover_removes_uncommitted_segments() {
        let run = mk_run("ingest-recover");
        // committed baseline: 1 wal segment
        fs::write(run.join("wal/wal-000000.seg"), [0u8; 32]).unwrap();
        let mut log = IngestLog::attach(&run, 5).unwrap();
        assert_eq!(log.committed_wal_segments(), 1);
        // torn round: extra wal segment + doc segment, no entries
        fs::write(run.join("wal/wal-000001.seg"), [0u8; 32]).unwrap();
        fs::write(run.join("wal/wal-000001.seg.sum"), b"{}").unwrap();
        fs::write(run.join("ingest/docs-000099.seg"), b"{}").unwrap();
        let report = recover(&run, &log).unwrap();
        assert_eq!(
            report,
            RecoveryReport {
                wal_segments_removed: 1,
                doc_segments_removed: 1
            }
        );
        assert!(!run.join("wal/wal-000001.seg").exists());
        assert!(!run.join("ingest/docs-000099.seg").exists());
        // idempotent, and committed artifacts survive
        assert_eq!(recover(&run, &log).unwrap(), RecoveryReport::default());
        assert!(run.join("wal/wal-000000.seg").exists());
        // a committed doc segment is never touched
        log.append_docs(1, 5, &[IngestDoc { user: 1, text: "t".into() }])
            .unwrap();
        assert_eq!(recover(&run, &log).unwrap(), RecoveryReport::default());
        assert!(run.join("ingest/docs-000001.seg").exists());
    }

    #[test]
    fn increment_schedule_is_pure_and_restamped() {
        let ts = TrainStep {
            from_step: 12,
            n_steps: 3,
        };
        let a = increment_schedule(40, 4, 2, 99, ts);
        let b = increment_schedule(40, 4, 2, 99, ts);
        assert_eq!(a, b);
        assert_eq!(a.len(), 6);
        assert_eq!(a[0].step, 12);
        assert_eq!(a.last().unwrap().step, 14);
        assert!(a.last().unwrap().accum_end);
        // a different tail position draws a different program
        let c = increment_schedule(
            40,
            4,
            2,
            99,
            TrainStep {
                from_step: 15,
                n_steps: 3,
            },
        );
        assert_ne!(
            a.iter().map(|m| &m.sample_ids).collect::<Vec<_>>(),
            c.iter().map(|m| &m.sample_ids).collect::<Vec<_>>()
        );
    }

    #[test]
    fn staged_idmap_promotes_iff_committed() {
        let run = mk_run("ingest-stage");
        let mut log = IngestLog::attach(&run, 5).unwrap();
        let stage = run.join("ingest/idmap.stage");
        let mk_stage = |bytes: &[u8]| {
            fs::create_dir_all(&stage).unwrap();
            fs::write(stage.join("round.json"), b"{\"round\": 9}").unwrap();
            fs::write(stage.join("ids.map"), bytes).unwrap();
        };
        // uncommitted round: the stage is discarded, the live map
        // (absent here) is untouched
        mk_stage(b"staged-a");
        recover(&run, &log).unwrap();
        assert!(!stage.exists());
        assert!(!run.join("ids.map").exists());
        // committed round: the stage is promoted over the live map
        log.commit(InterleaveEntry::Train {
            seq: log.next_seq,
            round: 9,
            from_step: 4,
            n_steps: 1,
            corpus_len: 5,
            wal_segments: 0,
            applied_updates: 5,
        })
        .unwrap();
        mk_stage(b"staged-b");
        recover(&run, &log).unwrap();
        assert!(!stage.exists());
        assert_eq!(fs::read(run.join("ids.map")).unwrap(), b"staged-b");
        // idempotent: a second recover with nothing staged is a no-op
        recover(&run, &log).unwrap();
        assert_eq!(fs::read(run.join("ids.map")).unwrap(), b"staged-b");
    }

    #[test]
    fn round_keys_are_stable() {
        assert_eq!(round_of("job-1"), round_of("job-1"));
        assert_ne!(round_of("job-1"), round_of("job-2"));
    }
}
