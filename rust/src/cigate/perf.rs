//! Performance regression gate over recorded bench baselines.
//!
//! The `--json` smoke mode of every `rust/benches/bench_*.rs` binary
//! emits a `BENCH_<name>.json` summary (ns/op, bytes/step, compress
//! ratio) that is committed next to the crate, tracking the perf
//! trajectory across PRs.  Before overwriting its baseline,
//! `bench_replay` runs [`check_replay`]: a measured per-step replay
//! latency more than [`DEFAULT_MAX_REGRESSION`] above the recorded
//! baseline refuses the run (non-zero exit), the same fail-closed
//! posture as the determinism gate.

use std::path::Path;

use crate::util::json::{parse, Json};

/// Allowed relative slowdown vs the recorded baseline (0.20 = +20%).
pub const DEFAULT_MAX_REGRESSION: f64 = 0.20;

/// A recorded replay-bench baseline.  `replay_ns_per_step` is `None`
/// for a placeholder file (schema committed before any measured run —
/// the first measured run records, later runs gate).
#[derive(Debug, Clone, PartialEq)]
pub struct PerfBaseline {
    pub replay_ns_per_step: Option<f64>,
}

/// Load a baseline from a `BENCH_replay.json` file.  Returns `None`
/// when the file does not exist; a present-but-null metric loads as a
/// record-only baseline.
pub fn load_baseline(path: &Path) -> anyhow::Result<Option<PerfBaseline>> {
    if !path.exists() {
        return Ok(None);
    }
    let j = parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("bench baseline {}: {e}", path.display()))?;
    Ok(Some(PerfBaseline {
        replay_ns_per_step: j
            .get("replay_ns_per_step")
            .and_then(|v| v.as_f64())
            .filter(|v| v.is_finite() && *v > 0.0),
    }))
}

/// The gate decision for one measured value against one baseline.
#[derive(Debug, Clone, PartialEq)]
pub enum PerfVerdict {
    /// No usable baseline — record the measurement, nothing to compare.
    RecordOnly,
    /// Within the allowed band (`ratio` = measured / baseline).
    Pass { ratio: f64 },
    /// Regressed beyond the band — refuse.
    Fail { ratio: f64 },
}

/// Compare a measured ns/step against a baseline.
pub fn judge(
    baseline: Option<f64>,
    measured_ns: f64,
    max_regression: f64,
) -> PerfVerdict {
    match baseline {
        None => PerfVerdict::RecordOnly,
        Some(b) if !(b.is_finite() && b > 0.0) => PerfVerdict::RecordOnly,
        Some(b) => {
            let ratio = measured_ns / b;
            if ratio <= 1.0 + max_regression {
                PerfVerdict::Pass { ratio }
            } else {
                PerfVerdict::Fail { ratio }
            }
        }
    }
}

/// Load one named numeric metric from a committed bench baseline.
/// `None` when the file is missing or the metric is null/invalid — the
/// record-only placeholder state.  Unlike the latency-only
/// [`load_baseline`], a recorded 0.0 is a VALID measurement here
/// (count metrics like replay-steps/request can legitimately be zero);
/// treating it as a placeholder would disable the gate forever and
/// churn the committed baseline on every run.
pub fn load_metric(path: &Path, key: &str) -> anyhow::Result<Option<f64>> {
    if !path.exists() {
        return Ok(None);
    }
    let j = parse(&std::fs::read_to_string(path)?)
        .map_err(|e| anyhow::anyhow!("bench baseline {}: {e}", path.display()))?;
    Ok(j.get(key)
        .and_then(|v| v.as_f64())
        .filter(|v| v.is_finite() && *v >= 0.0))
}

/// Generic fail-closed gate over one named metric of a committed bench
/// baseline: errors when `measured` regressed more than
/// `max_regression` over the recorded value.  `what` names the metric
/// in the refusal message.  A zero baseline gates exactly: any
/// positive measurement is a regression from zero.
pub fn check_metric(
    baseline_path: &Path,
    key: &str,
    measured: f64,
    max_regression: f64,
    what: &str,
) -> anyhow::Result<PerfVerdict> {
    let baseline = load_metric(baseline_path, key)?;
    if baseline == Some(0.0) {
        if measured <= 0.0 {
            return Ok(PerfVerdict::Pass { ratio: 1.0 });
        }
        anyhow::bail!(
            "{what} regressed: {measured:.2} vs a recorded baseline of 0 \
             — refusing ({})",
            baseline_path.display()
        );
    }
    let v = judge(baseline, measured, max_regression);
    if let PerfVerdict::Fail { ratio } = &v {
        anyhow::bail!(
            "{what} regressed: {measured:.2} is {:.1}% over the recorded \
             baseline (allowed +{:.0}%) — refusing ({})",
            (ratio - 1.0) * 100.0,
            max_regression * 100.0,
            baseline_path.display()
        );
    }
    Ok(v)
}

/// Fail-closed wrapper: error when the replay bench regressed more
/// than `max_regression` against the baseline at `baseline_path`.
pub fn check_replay(
    baseline_path: &Path,
    measured_ns: f64,
    max_regression: f64,
) -> anyhow::Result<PerfVerdict> {
    check_metric(
        baseline_path,
        "replay_ns_per_step",
        measured_ns,
        max_regression,
        "replay bench (ns/step)",
    )
}

/// The fleet bench's gated metric: replay-work-per-request across the
/// fleet (microbatch updates applied per forget request at the gate's
/// reference shard count).  A deterministic count, not a timing — it
/// regresses when routing gets leakier (more shards touched) or
/// per-shard rebuild tails grow, never from machine noise.
pub const FLEET_METRIC: &str = "fleet_replay_steps_per_request";

/// Fail-closed gate over the committed `BENCH_fleet.json` baseline.
pub fn check_fleet(
    baseline_path: &Path,
    measured_steps_per_request: f64,
    max_regression: f64,
) -> anyhow::Result<PerfVerdict> {
    check_metric(
        baseline_path,
        FLEET_METRIC,
        measured_steps_per_request,
        max_regression,
        "fleet bench (replay steps/request)",
    )
}

/// The WAL bench's second gated metric: nanoseconds for
/// `JobQueue::with_wal` to recover a jobs WAL holding a fixed pending
/// backlog (parse + re-queue under original ids + compaction rewrite).
/// This is the restart-to-serving latency of the durable admin queue —
/// it regresses when recovery starts re-parsing history it should have
/// compacted away or the rewrite stops being one atomic pass.
pub const WAL_RECOVERY_METRIC: &str = "recovery_replay_ns";

/// Fail-closed gate over the committed `BENCH_wal.json` baseline.
pub fn check_wal_recovery(
    baseline_path: &Path,
    measured_ns: f64,
    max_regression: f64,
) -> anyhow::Result<PerfVerdict> {
    check_metric(
        baseline_path,
        WAL_RECOVERY_METRIC,
        measured_ns,
        max_regression,
        "wal bench (jobs-WAL recovery ns)",
    )
}

/// The server bench's gated metric: nanoseconds per request through
/// the event-loop admin plane at the gate's reference concurrency
/// (32 connections, mixed submit/poll/status workload).  It regresses
/// when the hot dispatch path starts allocating trees again or the
/// poll loop loses fairness under many connections.
pub const SERVER_METRIC: &str = "event_loop_ns_per_request";

/// Fail-closed gate over the committed `BENCH_server.json` baseline.
pub fn check_server(
    baseline_path: &Path,
    measured_ns_per_request: f64,
    max_regression: f64,
) -> anyhow::Result<PerfVerdict> {
    check_metric(
        baseline_path,
        SERVER_METRIC,
        measured_ns_per_request,
        max_regression,
        "server bench (event-loop ns/request)",
    )
}

/// The replica bench's gated metric: the erasure-propagation SLA —
/// wall-clock milliseconds from forget submission until EVERY attached
/// read replica serves the laundered (clean) lineage.  This is the
/// number a regulator actually cares about: it regresses when launder
/// replay slows down, when replica sync stops being a byte-level diff
/// (dedup loss re-ships whole checkpoints), or when invalidation stops
/// piggybacking on the lineage swap.
pub const REPLICA_METRIC: &str = "erasure_propagation_ms";

/// Fail-closed gate over the committed `BENCH_replica.json` baseline.
pub fn check_replica(
    baseline_path: &Path,
    measured_ms: f64,
    max_regression: f64,
) -> anyhow::Result<PerfVerdict> {
    check_metric(
        baseline_path,
        REPLICA_METRIC,
        measured_ms,
        max_regression,
        "replica bench (erasure propagation ms)",
    )
}

/// The ingest bench's gated metric: wall-clock milliseconds to execute
/// one forget request under a **moving tail** — after interleaved
/// online-ingest rounds have appended doc segments and bounded
/// train-increments have extended the logged program past the original
/// run.  It regresses when the preserved-graph replay stops reusing
/// the nearest checkpoint below the divergence point, when closure
/// expansion over the incrementally-grown near-dup index slows, or
/// when interleave-log bookkeeping leaks onto the forget hot path.
pub const INGEST_METRIC: &str = "ingest_forget_ms";

/// Fail-closed gate over the committed `BENCH_ingest.json` baseline.
pub fn check_ingest(
    baseline_path: &Path,
    measured_ms: f64,
    max_regression: f64,
) -> anyhow::Result<PerfVerdict> {
    check_metric(
        baseline_path,
        INGEST_METRIC,
        measured_ms,
        max_regression,
        "ingest bench (forget-under-moving-tail ms)",
    )
}

/// Whether a measured run became the committed baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineDisposition {
    /// The committed file was absent or a null placeholder: this run's
    /// numbers were written as the first measured baseline.
    Recorded,
    /// A real baseline already exists; the file was left untouched
    /// (the caller decides whether to refresh it after passing the
    /// regression gate).
    AlreadyMeasured,
}

/// Resolve the placeholder-baseline state explicitly: when the
/// committed `BENCH_replay.json` is missing or carries null metrics
/// (the record-only placeholders committed on toolchain-less hosts),
/// write `measured` as the first real baseline so the >20% regression
/// gate starts biting from the next run — and report that it happened
/// instead of silently passing forever.  An existing measured baseline
/// is never overwritten here.
pub fn record_first_baseline(
    path: &Path,
    measured: &Json,
) -> anyhow::Result<BaselineDisposition> {
    record_first_baseline_for(path, "replay_ns_per_step", measured)
}

/// [`record_first_baseline`] generalized to any gated metric key —
/// the fleet bench promotes `fleet_replay_steps_per_request` through
/// the same missing-or-null-placeholder rule.
pub fn record_first_baseline_for(
    path: &Path,
    key: &str,
    measured: &Json,
) -> anyhow::Result<BaselineDisposition> {
    match load_metric(path, key)? {
        Some(_) => Ok(BaselineDisposition::AlreadyMeasured),
        None => {
            std::fs::write(path, measured.pretty())?;
            Ok(BaselineDisposition::Recorded)
        }
    }
}

/// The `BENCH_replay.json` document for a measured run.
/// `replay_ns_per_step` is the DEFAULT path — segment-parallel
/// dispatch through `grad_accumulate` — and is what the regression
/// gate reads; `ns_per_step_sequential` (schema 2) records the forced
/// sequential traversal so the speedup lands in the committed history.
pub fn replay_json(ns_per_step: f64, t_step_ns: f64, steps: u32) -> Json {
    let mut j = Json::obj();
    j.set("bench", "replay")
        .set("replay_ns_per_step", ns_per_step)
        .set("train_t_step_ns", t_step_ns)
        .set("steps", steps)
        .set("schema", 2);
    j
}

/// Attach the sequential-traversal A/B numbers to a
/// [`replay_json`] document.
pub fn set_replay_ab(j: &mut Json, ns_sequential: f64, ns_parallel: f64) {
    j.set("replay_ns_per_step_sequential", ns_sequential)
        .set(
            "parallel_speedup",
            if ns_parallel > 0.0 {
                Json::from(ns_sequential / ns_parallel)
            } else {
                Json::Null
            },
        );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;

    #[test]
    fn fleet_metric_gates_and_promotes_like_replay() {
        let dir = tempdir("perf-fleet-gate");
        let path = dir.join("BENCH_fleet.json");
        // missing file: record-only
        assert_eq!(
            check_fleet(&path, 5.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        // committed null placeholder: record-only, then promoted
        std::fs::write(
            &path,
            r#"{"bench": "fleet", "fleet_replay_steps_per_request": null}"#,
        )
        .unwrap();
        assert_eq!(
            check_fleet(&path, 5.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        let mut measured = Json::obj();
        measured
            .set("bench", "fleet")
            .set(FLEET_METRIC, 5.0)
            .set("schema", 1);
        assert_eq!(
            record_first_baseline_for(&path, FLEET_METRIC, &measured)
                .unwrap(),
            BaselineDisposition::Recorded
        );
        assert_eq!(load_metric(&path, FLEET_METRIC).unwrap(), Some(5.0));
        // once real, the same >20% band bites — and the baseline is
        // never clobbered by the promoter
        assert!(matches!(
            check_fleet(&path, 5.9, 0.2).unwrap(),
            PerfVerdict::Pass { .. }
        ));
        assert!(check_fleet(&path, 6.5, 0.2).is_err());
        let other = {
            let mut j = Json::obj();
            j.set(FLEET_METRIC, 1.0);
            j
        };
        assert_eq!(
            record_first_baseline_for(&path, FLEET_METRIC, &other).unwrap(),
            BaselineDisposition::AlreadyMeasured
        );
        assert_eq!(load_metric(&path, FLEET_METRIC).unwrap(), Some(5.0));
    }

    #[test]
    fn server_metric_gates_and_promotes() {
        let dir = tempdir("perf-server-gate");
        let path = dir.join("BENCH_server.json");
        assert_eq!(
            check_server(&path, 900.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        std::fs::write(
            &path,
            r#"{"bench": "server", "event_loop_ns_per_request": null}"#,
        )
        .unwrap();
        assert_eq!(
            check_server(&path, 900.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        let mut measured = Json::obj();
        measured
            .set("bench", "server")
            .set(SERVER_METRIC, 900.0)
            .set("schema", 1);
        assert_eq!(
            record_first_baseline_for(&path, SERVER_METRIC, &measured)
                .unwrap(),
            BaselineDisposition::Recorded
        );
        assert!(matches!(
            check_server(&path, 1000.0, 0.2).unwrap(),
            PerfVerdict::Pass { .. }
        ));
        assert!(check_server(&path, 1200.0, 0.2).is_err());
    }

    #[test]
    fn replica_metric_gates_and_promotes() {
        let dir = tempdir("perf-replica-gate");
        let path = dir.join("BENCH_replica.json");
        assert_eq!(
            check_replica(&path, 40.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        std::fs::write(
            &path,
            r#"{"bench": "replica", "erasure_propagation_ms": null}"#,
        )
        .unwrap();
        assert_eq!(
            check_replica(&path, 40.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        let mut measured = Json::obj();
        measured
            .set("bench", "replica")
            .set(REPLICA_METRIC, 40.0)
            .set("schema", 1);
        assert_eq!(
            record_first_baseline_for(&path, REPLICA_METRIC, &measured)
                .unwrap(),
            BaselineDisposition::Recorded
        );
        assert!(matches!(
            check_replica(&path, 44.0, 0.2).unwrap(),
            PerfVerdict::Pass { .. }
        ));
        assert!(check_replica(&path, 60.0, 0.2).is_err());
    }

    #[test]
    fn ingest_metric_gates_and_promotes() {
        let dir = tempdir("perf-ingest-gate");
        let path = dir.join("BENCH_ingest.json");
        assert_eq!(
            check_ingest(&path, 25.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        std::fs::write(
            &path,
            r#"{"bench": "ingest", "ingest_forget_ms": null}"#,
        )
        .unwrap();
        assert_eq!(
            check_ingest(&path, 25.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        let mut measured = Json::obj();
        measured
            .set("bench", "ingest")
            .set(INGEST_METRIC, 25.0)
            .set("schema", 1);
        assert_eq!(
            record_first_baseline_for(&path, INGEST_METRIC, &measured)
                .unwrap(),
            BaselineDisposition::Recorded
        );
        assert!(matches!(
            check_ingest(&path, 29.0, 0.2).unwrap(),
            PerfVerdict::Pass { .. }
        ));
        assert!(check_ingest(&path, 40.0, 0.2).is_err());
        let other = {
            let mut j = Json::obj();
            j.set(INGEST_METRIC, 1.0);
            j
        };
        assert_eq!(
            record_first_baseline_for(&path, INGEST_METRIC, &other).unwrap(),
            BaselineDisposition::AlreadyMeasured,
            "a measured ingest baseline is never clobbered"
        );
        assert_eq!(load_metric(&path, INGEST_METRIC).unwrap(), Some(25.0));
    }

    #[test]
    fn zero_count_baseline_is_measured_and_gates_exactly() {
        // 0 is a legitimate measurement for a count metric: it must be
        // recorded ONCE (no baseline churn) and any positive later
        // measurement is a regression from zero.
        let dir = tempdir("perf-fleet-zero");
        let path = dir.join("BENCH_fleet.json");
        let mut zero = Json::obj();
        zero.set(FLEET_METRIC, 0.0);
        assert_eq!(
            record_first_baseline_for(&path, FLEET_METRIC, &zero).unwrap(),
            BaselineDisposition::Recorded
        );
        // the recorded zero is a real baseline, not a placeholder
        assert_eq!(load_metric(&path, FLEET_METRIC).unwrap(), Some(0.0));
        let mut other = Json::obj();
        other.set(FLEET_METRIC, 3.0);
        assert_eq!(
            record_first_baseline_for(&path, FLEET_METRIC, &other).unwrap(),
            BaselineDisposition::AlreadyMeasured,
            "a zero baseline must not churn"
        );
        assert!(matches!(
            check_fleet(&path, 0.0, 0.2).unwrap(),
            PerfVerdict::Pass { .. }
        ));
        assert!(
            check_fleet(&path, 1.0, 0.2).is_err(),
            "any positive measurement regresses a zero baseline"
        );
    }

    #[test]
    fn no_baseline_is_record_only() {
        assert_eq!(judge(None, 100.0, 0.2), PerfVerdict::RecordOnly);
        assert_eq!(judge(Some(0.0), 100.0, 0.2), PerfVerdict::RecordOnly);
        assert_eq!(
            judge(Some(f64::NAN), 100.0, 0.2),
            PerfVerdict::RecordOnly
        );
    }

    #[test]
    fn within_band_passes_beyond_fails() {
        assert!(matches!(
            judge(Some(100.0), 119.0, 0.2),
            PerfVerdict::Pass { .. }
        ));
        assert!(matches!(
            judge(Some(100.0), 121.0, 0.2),
            PerfVerdict::Fail { .. }
        ));
        // faster is always fine
        assert!(matches!(
            judge(Some(100.0), 40.0, 0.2),
            PerfVerdict::Pass { .. }
        ));
    }

    #[test]
    fn check_replay_fails_closed_on_regression() {
        let dir = tempdir("perf-gate");
        let path = dir.join("BENCH_replay.json");
        // missing file: record-only
        assert_eq!(
            check_replay(&path, 500.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        // placeholder with null metric: record-only
        std::fs::write(
            &path,
            r#"{"bench": "replay", "replay_ns_per_step": null}"#,
        )
        .unwrap();
        assert_eq!(
            check_replay(&path, 500.0, 0.2).unwrap(),
            PerfVerdict::RecordOnly
        );
        // recorded baseline gates
        std::fs::write(&path, replay_json(400.0, 100.0, 12).pretty()).unwrap();
        assert!(matches!(
            check_replay(&path, 450.0, 0.2).unwrap(),
            PerfVerdict::Pass { .. }
        ));
        assert!(check_replay(&path, 1000.0, 0.2).is_err());
    }

    #[test]
    fn first_measured_run_fills_a_placeholder_baseline() {
        let dir = tempdir("perf-first-baseline");
        let path = dir.join("BENCH_replay.json");
        let measured = replay_json(777.0, 100.0, 12);

        // missing file: the measured run becomes the baseline
        assert_eq!(
            record_first_baseline(&path, &measured).unwrap(),
            BaselineDisposition::Recorded
        );
        assert_eq!(
            load_baseline(&path).unwrap().unwrap().replay_ns_per_step,
            Some(777.0)
        );

        // committed null placeholder: same promotion
        std::fs::write(
            &path,
            r#"{"bench": "replay", "replay_ns_per_step": null}"#,
        )
        .unwrap();
        assert_eq!(
            record_first_baseline(&path, &measured).unwrap(),
            BaselineDisposition::Recorded
        );

        // once real, the gate bites and the baseline is NOT replaced
        assert!(check_replay(&path, 2000.0, 0.2).is_err());
        let other = replay_json(1.0, 1.0, 1);
        assert_eq!(
            record_first_baseline(&path, &other).unwrap(),
            BaselineDisposition::AlreadyMeasured
        );
        assert_eq!(
            load_baseline(&path).unwrap().unwrap().replay_ns_per_step,
            Some(777.0),
            "a measured baseline is never clobbered by the promoter"
        );
    }

    #[test]
    fn baseline_roundtrips_through_json() {
        let dir = tempdir("perf-roundtrip");
        let path = dir.join("BENCH_replay.json");
        std::fs::write(&path, replay_json(123.0, 45.0, 10).pretty()).unwrap();
        let b = load_baseline(&path).unwrap().unwrap();
        assert_eq!(b.replay_ns_per_step, Some(123.0));
    }
}
