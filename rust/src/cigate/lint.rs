//! Baseline gate for `detlint` findings — the static-analysis sibling
//! of `cigate::perf`.
//!
//! The committed baseline (`rust/detlint-baseline.json`, schema-
//! versioned) records every finding the repo has consciously accepted.
//! The gate fails on any finding NOT in the baseline ("zero new
//! findings") and reports how many baselined findings disappeared so
//! the baseline can be ratcheted down (re-run with `--write-baseline`
//! after fixing — never to absorb new findings).
//!
//! Matching is by `(rule, file, sha256(trimmed snippet))` with
//! multiplicity, NOT by line number: unrelated edits that shift a
//! baselined finding up or down the file do not break the gate, while
//! a new occurrence of the same pattern elsewhere in the file (a new
//! snippet, or a second identical one beyond the recorded count) does.

use std::collections::BTreeMap;
use std::path::Path;

use crate::lint::Finding;
use crate::util::hashing::sha256_hex;
use crate::util::json::{parse, Json};

/// Bump when the baseline layout changes; loading a mismatched schema
/// is an error (fail closed — never silently gate against a file the
/// current code cannot interpret).
pub const BASELINE_SCHEMA: u64 = 1;

/// Stable identity of a finding for baseline matching.
pub fn baseline_key(f: &Finding) -> String {
    let id = format!("{}\u{0}{}\u{0}{}", f.rule, f.file, f.snippet.trim());
    sha256_hex(id.as_bytes())
}

/// Serialize findings into the committed baseline format.  Entries are
/// grouped by key with a count, sorted by (rule, file, key) — the byte
/// image is deterministic for a given finding set.
pub fn baseline_json(findings: &[Finding]) -> Json {
    // key -> (rule, file, snippet, count)
    let mut grouped: BTreeMap<String, (String, String, String, u64)> = BTreeMap::new();
    for f in findings {
        let e = grouped.entry(baseline_key(f)).or_insert_with(|| {
            (f.rule.to_string(), f.file.clone(), f.snippet.trim().to_string(), 0)
        });
        e.3 += 1;
    }
    let mut entries: Vec<(String, (String, String, String, u64))> =
        grouped.into_iter().collect();
    entries.sort_by(|a, b| {
        (&a.1 .0, &a.1 .1, &a.0).cmp(&(&b.1 .0, &b.1 .1, &b.0))
    });
    let arr: Vec<Json> = entries
        .into_iter()
        .map(|(key, (rule, file, snippet, count))| {
            let mut o = Json::obj();
            o.set("rule", rule.as_str())
                .set("file", file.as_str())
                .set("snippet", snippet.as_str())
                .set("snippet_sha256", key.as_str())
                .set("count", count);
            o
        })
        .collect();
    let mut out = Json::obj();
    out.set("schema", BASELINE_SCHEMA)
        .set("tool", "detlint")
        .set("findings", Json::Arr(arr));
    out
}

pub fn write_baseline(path: &Path, findings: &[Finding]) -> anyhow::Result<()> {
    // trailing newline so the regenerated file byte-matches the
    // committed artifact convention
    std::fs::write(path, baseline_json(findings).pretty() + "\n")?;
    Ok(())
}

/// Load a baseline as `key -> allowed count`.  A missing file is an
/// empty baseline (the gate then demands a fully clean scan); a
/// present-but-unreadable file or a schema mismatch is an error.
pub fn load_baseline(path: &Path) -> anyhow::Result<BTreeMap<String, u64>> {
    if !path.exists() {
        return Ok(BTreeMap::new());
    }
    let text = std::fs::read_to_string(path)?;
    let json = parse(&text)
        .map_err(|e| anyhow::anyhow!("unparseable baseline {}: {e}", path.display()))?;
    let schema = json.get("schema").and_then(|j| j.as_u64()).unwrap_or(0);
    anyhow::ensure!(
        schema == BASELINE_SCHEMA,
        "baseline {} has schema {schema}, this detlint understands {BASELINE_SCHEMA}",
        path.display()
    );
    let mut out = BTreeMap::new();
    for e in json.get("findings").and_then(|j| j.as_arr()).unwrap_or(&[]) {
        let key = e
            .get("snippet_sha256")
            .and_then(|j| j.as_str())
            .ok_or_else(|| anyhow::anyhow!("baseline entry missing snippet_sha256"))?;
        let count = e.get("count").and_then(|j| j.as_u64()).unwrap_or(1);
        *out.entry(key.to_string()).or_insert(0) += count;
    }
    Ok(out)
}

/// Gate verdict: which findings are new vs baselined, and how many
/// baseline entries no longer fire (the ratchet opportunity).
#[derive(Debug)]
pub struct LintGate {
    /// Findings not covered by the baseline — these fail CI.
    pub new: Vec<Finding>,
    /// Findings absorbed by the baseline.
    pub baselined: usize,
    /// Baseline capacity that nothing matched (fixed findings); when
    /// nonzero the baseline should be ratcheted down.
    pub fixed: u64,
}

impl LintGate {
    pub fn pass(&self) -> bool {
        self.new.is_empty()
    }
}

/// Match `findings` against `baseline` with per-key multiplicity.
pub fn gate(findings: &[Finding], baseline: &BTreeMap<String, u64>) -> LintGate {
    let mut remaining = baseline.clone();
    let mut out = LintGate {
        new: Vec::new(),
        baselined: 0,
        fixed: 0,
    };
    for f in findings {
        match remaining.get_mut(&baseline_key(f)) {
            Some(n) if *n > 0 => {
                *n -= 1;
                out.baselined += 1;
            }
            _ => out.new.push(f.clone()),
        }
    }
    out.fixed = remaining.values().sum();
    out
}

pub fn gate_against_file(findings: &[Finding], path: &Path) -> anyhow::Result<LintGate> {
    Ok(gate(findings, &load_baseline(path)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;

    fn finding(rule: &'static str, file: &str, snippet: &str) -> Finding {
        Finding {
            rule,
            file: file.to_string(),
            line: 1,
            col: 1,
            message: "m".to_string(),
            snippet: snippet.to_string(),
        }
    }

    /// Write → load → gate round-trip: everything baselined, no new.
    #[test]
    fn baseline_roundtrip() {
        let dir = tempdir("lint-baseline");
        let path = dir.join("baseline.json");
        let fs = vec![
            finding(crate::lint::rules::RULE_WALL_CLOCK, "a.rs", "Instant::now();"),
            finding(crate::lint::rules::RULE_RAW_FS, "wal/x.rs", "fs::write(p, b)?;"),
            finding(crate::lint::rules::RULE_RAW_FS, "wal/x.rs", "fs::write(p, b)?;"),
        ];
        write_baseline(&path, &fs).unwrap();
        let g = gate_against_file(&fs, &path).unwrap();
        assert!(g.pass());
        assert_eq!(g.baselined, 3);
        assert_eq!(g.fixed, 0);
    }

    /// Line drift is harmless; a NEW snippet or an extra copy of a
    /// baselined one is not.
    #[test]
    fn gate_flags_new_and_extra_findings() {
        let dir = tempdir("lint-gate");
        let path = dir.join("baseline.json");
        let base = vec![finding(
            crate::lint::rules::RULE_WALL_CLOCK,
            "a.rs",
            "Instant::now();",
        )];
        write_baseline(&path, &base).unwrap();

        // same snippet, different line: still baselined
        let mut moved = base.clone();
        moved[0].line = 99;
        assert!(gate_against_file(&moved, &path).unwrap().pass());

        // second copy of the same snippet exceeds the recorded count
        let two = vec![base[0].clone(), base[0].clone()];
        let g = gate_against_file(&two, &path).unwrap();
        assert_eq!(g.new.len(), 1);
        assert!(!g.pass());

        // different snippet is new
        let other = vec![finding(
            crate::lint::rules::RULE_WALL_CLOCK,
            "a.rs",
            "SystemTime::now();",
        )];
        assert!(!gate_against_file(&other, &path).unwrap().pass());
    }

    /// Fixed findings surface as ratchet capacity; missing baseline
    /// file means empty baseline; wrong schema fails closed.
    #[test]
    fn ratchet_missing_and_schema() {
        let dir = tempdir("lint-schema");
        let path = dir.join("baseline.json");
        let base = vec![
            finding(crate::lint::rules::RULE_ENTROPY, "a.rs", "thread_rng()"),
            finding(crate::lint::rules::RULE_ENTROPY, "b.rs", "thread_rng()"),
        ];
        write_baseline(&path, &base).unwrap();
        let g = gate_against_file(&base[..1], &path).unwrap();
        assert!(g.pass());
        assert_eq!(g.fixed, 1);

        let missing = gate_against_file(&base[..1], &dir.join("nope.json")).unwrap();
        assert_eq!(missing.new.len(), 1);

        std::fs::write(&path, "{\"schema\": 999, \"findings\": []}").unwrap();
        assert!(gate_against_file(&base, &path).is_err());
    }
}
