//! Determinism/Replay CI gate (paper Alg. 5.1 / A.8, Fig. 2).
//!
//! Run before forgetting is enabled:
//!   1. train T steps twice under identical pins → byte-identical
//!      weights AND optimizer state;
//!   2. from a checkpoint C_k, run `ReplayFilter` with an empty closure
//!      → byte-identical to the direct run;
//!   3. WAL integrity scan (CRC per record, segment SHA/HMAC, monotone
//!      gap-free `opt_step_u32`).
//! Any mismatch blocks forgetting (fail-closed).

use std::collections::HashSet;

use crate::checkpoint::CheckpointStore;
use crate::config::RunConfig;
use crate::data::corpus::Corpus;
use crate::replay::{load_run, replay_filter, ReplayOptions};
use crate::runtime::Runtime;
use crate::trainer::Trainer;
use crate::util::json::Json;
use crate::wal::integrity;

pub mod lint;
pub mod perf;

/// Outcome of the CI gate.
#[derive(Debug, Clone)]
pub struct CiGateReport {
    pub train_train_equal: bool,
    pub checkpoint_replay_equal: bool,
    pub wal_integrity_ok: bool,
    pub details: Vec<String>,
}

impl CiGateReport {
    pub fn pass(&self) -> bool {
        self.train_train_equal
            && self.checkpoint_replay_equal
            && self.wal_integrity_ok
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("pass", self.pass())
            .set("train_train_equal", self.train_train_equal)
            .set("checkpoint_replay_equal", self.checkpoint_replay_equal)
            .set("wal_integrity_ok", self.wal_integrity_ok)
            .set(
                "details",
                Json::Arr(
                    self.details
                        .iter()
                        .map(|d| Json::Str(d.clone()))
                        .collect(),
                ),
            );
        j
    }
}

/// Run the full gate.  `base_cfg.run_dir` is used as a prefix; the gate
/// writes `<run_dir>-cigate-{a,b}`.
pub fn run_gate(
    rt: &Runtime,
    base_cfg: &RunConfig,
    corpus: &Corpus,
    gate_steps: u32,
) -> anyhow::Result<CiGateReport> {
    let mut details = Vec::new();
    let mut cfg_a = base_cfg.clone();
    cfg_a.steps = gate_steps;
    cfg_a.run_dir = base_cfg.run_dir.with_file_name(format!(
        "{}-cigate-a",
        base_cfg
            .run_dir
            .file_name()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "run".into())
    ));
    let mut cfg_b = cfg_a.clone();
    cfg_b.run_dir = cfg_a.run_dir.with_file_name(
        cfg_a
            .run_dir
            .file_name()
            .unwrap()
            .to_string_lossy()
            .replace("-a", "-b"),
    );
    for d in [&cfg_a.run_dir, &cfg_b.run_dir] {
        if d.exists() {
            std::fs::remove_dir_all(d)?;
        }
    }

    // (1) train–train byte equality
    let out_a = Trainer::new(rt, cfg_a.clone(), corpus.clone()).train(|_| false)?;
    let out_b = Trainer::new(rt, cfg_b, corpus.clone()).train(|_| false)?;
    let train_train_equal = out_a.state.bits_equal(&out_b.state);
    details.push(format!(
        "train-train: model {} vs {}, opt {} vs {}",
        out_a.state.model_hash(),
        out_b.state.model_hash(),
        out_a.state.optimizer_hash(),
        out_b.state.optimizer_hash()
    ));

    // (2) checkpoint→replay equality (no filtering)
    let store = CheckpointStore::open(&cfg_a.run_dir.join("ckpt"), 64)?;
    let k = store
        .nearest_at_or_before(gate_steps / 2)
        .ok()
        .flatten()
        .unwrap_or(0);
    let ck = store.load_full(k)?;
    let (records, idmap, pins) = load_run(&cfg_a.run_dir, base_cfg.hmac_key.clone())?;
    let outcome = replay_filter(
        rt,
        corpus,
        &ck,
        &records,
        &idmap,
        &HashSet::new(),
        Some(&pins),
        // the gate runs were trained under the caller's topology claim
        // (if any); replay must present the same one or the pin check
        // would refuse a perfectly healthy fleet-shard config
        &ReplayOptions {
            shard_pin: base_cfg.shard_pin.clone(),
            ..ReplayOptions::default()
        },
    )?;
    let checkpoint_replay_equal = outcome.state.bits_equal(&out_a.state);
    details.push(format!(
        "ckpt-replay from step {k}: model {} vs {}",
        outcome.state.model_hash(),
        out_a.state.model_hash()
    ));

    // (3) WAL integrity
    let rep = integrity::scan(
        &cfg_a.run_dir.join("wal"),
        base_cfg.hmac_key.as_deref(),
    )?;
    let wal_integrity_ok = rep.ok();
    details.push(format!("wal scan: {}", rep.to_json().encode()));

    Ok(CiGateReport {
        train_train_equal,
        checkpoint_replay_equal,
        wal_integrity_ok,
        details,
    })
}
