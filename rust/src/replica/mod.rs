//! Serving data plane: lineage-synced read replicas.
//!
//! A [`Replica`] is a local mirror of one source shard's CAS
//! ([`crate::checkpoint`]) that serves read-only eval/loss queries.
//! It syncs **by lineage generation**: compare the local
//! `LINEAGE.json` generation to the source's, and if behind, pull the
//! active lineage — manifests verbatim plus only the CAS objects the
//! mirror is missing.  Content addressing makes the pull a pure
//! byte-level diff: after a launder, the rewritten tensors are the
//! only new objects, so the re-sync bill is the launder's actual
//! delta, not a full checkpoint (`tests/replica_sla.rs` asserts the
//! bound).
//!
//! Sync protocol (`pull → verify → adopt`, fail closed at every step):
//!
//! 1. [`checkpoint::export_snapshot`] reads the source's active
//!    lineage (generation, manifests, referenced object hashes).
//! 2. Missing objects are pulled through
//!    [`checkpoint::read_object_verified`] (source-side hash check)
//!    and [`checkpoint::import_object`] (sink-side re-hash; a torn or
//!    tampered transfer is refused).  Present objects cost zero bytes.
//! 3. [`checkpoint::begin_import`] clears any half-pulled remnant of
//!    the target generation, [`checkpoint::import_manifest`] stages
//!    the manifests verbatim, and [`checkpoint::adopt_generation`]
//!    re-verifies reachability of every referenced object before the
//!    single commit point — the atomic `LINEAGE.json` swap.
//!
//! A crash anywhere before the swap leaves the mirror serving the OLD
//! generation; the staged directory is retired by the next
//! [`CheckpointStore::open`] on the serving path (old-or-new, never a
//! mixed generation — `tests/crash_matrix.rs` sweeps every op).
//!
//! The query plane ([`serve_replica`]) rides `server::event_loop` and
//! `util::json_scan` like both admin planes.  Staleness is
//! **watermarked, not hidden**: every eval/loss response carries
//! `{generation, source_generation, lag, stale}` so a caller can see
//! it was answered from a pre-erasure lineage while a sync is in
//! flight.  A replica that never completed a sync refuses to serve.

use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::Mutex;

use crate::audit::{per_example_loss_counts, ModelView};
use crate::checkpoint::{self, CheckpointStore, TrainState};
use crate::data::corpus::Corpus;
use crate::runtime::Runtime;
use crate::server::scan_err;
use crate::util::json::Json;
use crate::util::json_scan;

/// One sync's transfer accounting — the dedup bound's witness.
#[derive(Debug, Clone, PartialEq)]
pub struct SyncStats {
    /// Local generation before the sync (`None` = cold mirror).
    pub from_generation: Option<u64>,
    /// Source generation the sync observed (and, unless
    /// `already_current`, adopted).
    pub to_generation: u64,
    /// The mirror was already at the source generation — nothing moved.
    pub already_current: bool,
    /// Objects actually transferred.
    pub objects_pulled: usize,
    /// Bytes actually transferred (the SLA's bytes-per-launder term).
    pub bytes_pulled: u64,
    /// Referenced objects already present locally (CAS dedup hits).
    pub objects_reused: usize,
    /// Bytes those dedup hits would have cost a mirror without content
    /// addressing.
    pub bytes_reused: u64,
    /// Manifest files staged (including `laundered.json` if present).
    pub manifests_pulled: usize,
    /// Wall time of the sync, milliseconds (monotonic clock).
    pub wall_ms: f64,
}

impl SyncStats {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        match self.from_generation {
            Some(g) => j.set("from_generation", g),
            None => j.set("from_generation", Json::Null),
        };
        j.set("to_generation", self.to_generation)
            .set("already_current", self.already_current)
            .set("objects_pulled", self.objects_pulled)
            .set("bytes_pulled", self.bytes_pulled)
            .set("objects_reused", self.objects_reused)
            .set("bytes_reused", self.bytes_reused)
            .set("manifests_pulled", self.manifests_pulled)
            .set("wall_ms", self.wall_ms);
        j
    }
}

/// A read replica: a local CAS mirror of one source store.
pub struct Replica {
    /// Source shard's CAS root (`<run dir>/ckpt`).
    pub source_root: PathBuf,
    /// This mirror's CAS root.
    pub local_root: PathBuf,
    /// Generation the mirror has fully adopted (`None` until the first
    /// completed sync — an unsynced replica refuses to serve).
    generation: Option<u64>,
    /// Accounting of the most recent [`Replica::sync`].
    last_sync: Option<SyncStats>,
    /// Completed sync calls (including already-current no-ops).
    syncs: u64,
}

impl Replica {
    /// Open (or create) a mirror of `source_root` at `local_root`.  An
    /// existing mirror resumes at whatever generation its own
    /// `LINEAGE.json` records; a half-pulled generation from a crashed
    /// sync is invisible here (the swap never happened) and is retired
    /// by the serving path's store open.
    pub fn open(source_root: &Path, local_root: &Path) -> anyhow::Result<Replica> {
        std::fs::create_dir_all(local_root)?;
        let generation = if local_root.join("LINEAGE.json").exists() {
            Some(checkpoint::read_generation(local_root)?)
        } else {
            None
        };
        Ok(Replica {
            source_root: source_root.to_path_buf(),
            local_root: local_root.to_path_buf(),
            generation,
            last_sync: None,
            syncs: 0,
        })
    }

    /// Generation the mirror serves (`None` = never synced).
    pub fn generation(&self) -> Option<u64> {
        self.generation
    }

    /// The source's current active generation (re-read every call, so
    /// a swap by the source process is observed immediately).
    pub fn source_generation(&self) -> anyhow::Result<u64> {
        checkpoint::read_generation(&self.source_root)
    }

    /// Generations the mirror is behind the source (0 = current; an
    /// unsynced mirror counts the source's whole history plus one).
    pub fn lag(&self) -> anyhow::Result<u64> {
        let src = self.source_generation()?;
        Ok(match self.generation {
            Some(g) => src.saturating_sub(g),
            None => src + 1,
        })
    }

    /// Accounting of the most recent sync.
    pub fn last_sync(&self) -> Option<&SyncStats> {
        self.last_sync.as_ref()
    }

    /// Pull the source's active lineage if the mirror is behind.
    /// Every object is hash-verified on read AND on ingest; objects
    /// already present locally are skipped (content addressing — the
    /// dedup bound).  The local `LINEAGE.json` swap is the last write:
    /// failure or crash anywhere earlier leaves the previous
    /// generation served, never a mix.
    pub fn sync(&mut self) -> anyhow::Result<SyncStats> {
        let t0 = crate::metrics::monotonic_now();
        let snap = checkpoint::export_snapshot(&self.source_root)?;
        let from = self.generation;
        let mut stats = SyncStats {
            from_generation: from,
            to_generation: snap.generation,
            already_current: from == Some(snap.generation),
            objects_pulled: 0,
            bytes_pulled: 0,
            objects_reused: 0,
            bytes_reused: 0,
            manifests_pulled: 0,
            wall_ms: 0.0,
        };
        if !stats.already_current {
            // objects first: adopt's reachability gate must see them
            for hash in &snap.object_hashes {
                if checkpoint::object_present(&self.local_root, hash) {
                    stats.objects_reused += 1;
                    stats.bytes_reused +=
                        checkpoint::object_len(&self.local_root, hash);
                } else {
                    let bytes = checkpoint::read_object_verified(
                        &self.source_root,
                        hash,
                    )?;
                    stats.bytes_pulled += bytes.len() as u64;
                    stats.objects_pulled += 1;
                    checkpoint::import_object(&self.local_root, hash, &bytes)?;
                }
            }
            checkpoint::begin_import(&self.local_root, snap.generation)?;
            for m in &snap.manifests {
                checkpoint::import_manifest(
                    &self.local_root,
                    snap.generation,
                    &m.name,
                    &m.contents,
                )?;
                stats.manifests_pulled += 1;
            }
            if let Some(l) = &snap.laundered {
                checkpoint::import_manifest(
                    &self.local_root,
                    snap.generation,
                    "laundered.json",
                    l,
                )?;
                stats.manifests_pulled += 1;
            }
            checkpoint::adopt_generation(&self.local_root, snap.generation)?;
            self.generation = Some(snap.generation);
        }
        stats.wall_ms = crate::metrics::monotonic_now()
            .saturating_duration_since(t0)
            .as_secs_f64()
            * 1e3;
        self.syncs += 1;
        self.last_sync = Some(stats.clone());
        Ok(stats)
    }

    /// Load the state this replica serves: the latest full checkpoint
    /// of its adopted generation.  Opening the store here is also the
    /// crash-recovery path — `CheckpointStore::open` retires any
    /// half-pulled non-active generation and re-verifies the active
    /// lineage's reachability, so a torn pull can never be served.
    pub fn load_serving_state(&self) -> anyhow::Result<ServingState> {
        let generation = self.generation.ok_or_else(|| {
            anyhow::anyhow!(
                "replica of {} has never completed a sync — refusing to \
                 serve (fail closed)",
                self.source_root.display()
            )
        })?;
        let store = CheckpointStore::open(&self.local_root, usize::MAX)?;
        let steps = store.list_full()?;
        let step = *steps.last().ok_or_else(|| {
            anyhow::anyhow!(
                "replica generation {generation} holds no full checkpoint"
            )
        })?;
        let state = store.load_full(step)?;
        Ok(ServingState {
            generation,
            step,
            state,
        })
    }

    /// Status row: `{synced, generation, source_generation, lag,
    /// stale, syncs, last_sync}` — the per-replica shape `fleet_status`
    /// embeds.
    pub fn status_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("synced", self.generation.is_some());
        match self.generation {
            Some(g) => j.set("generation", g),
            None => j.set("generation", Json::Null),
        };
        match self.source_generation() {
            Ok(src) => {
                let lag = match self.generation {
                    Some(g) => src.saturating_sub(g),
                    None => src + 1,
                };
                j.set("source_generation", src)
                    .set("lag", lag)
                    .set("stale", lag > 0);
            }
            Err(_) => {
                // an unreadable source is reported as stale, not hidden
                j.set("source_generation", Json::Null)
                    .set("lag", Json::Null)
                    .set("stale", true);
            }
        }
        j.set("syncs", self.syncs);
        match &self.last_sync {
            Some(s) => j.set("last_sync", s.to_json()),
            None => j.set("last_sync", Json::Null),
        };
        j
    }
}

/// The checkpoint a replica answers queries from.
pub struct ServingState {
    /// Lineage generation the state came from.
    pub generation: u64,
    /// Logical step of the served checkpoint.
    pub step: u32,
    /// The full restored state (params drive eval; optimizer moments
    /// ride along for bit-identity assertions).
    pub state: TrainState,
}

/// Mutable serving half of a replica server: the mirror plus its
/// lazily loaded checkpoint (dropped on every adopted sync so the next
/// query reloads from the new generation).
pub struct ReplicaServing {
    pub replica: Replica,
    pub state: Option<ServingState>,
}

/// Context of one replica query server.
pub struct ReplicaCtx<'rt> {
    pub rt: &'rt Runtime,
    /// The source shard's corpus (eval queries address samples by
    /// global id; an id outside this corpus is a typed refusal).
    pub corpus: Corpus,
    pub serving: Mutex<ReplicaServing>,
    pub shutdown: AtomicBool,
}

impl<'rt> ReplicaCtx<'rt> {
    pub fn new(rt: &'rt Runtime, corpus: Corpus, replica: Replica) -> Self {
        ReplicaCtx {
            rt,
            corpus,
            serving: Mutex::new(ReplicaServing {
                replica,
                state: None,
            }),
            shutdown: AtomicBool::new(false),
        }
    }
}

/// Load (once) the serving state behind the lock.
fn ensure_loaded(serving: &mut ReplicaServing) -> anyhow::Result<&ServingState> {
    if serving.state.is_none() {
        serving.state = Some(serving.replica.load_serving_state()?);
    }
    Ok(serving.state.as_ref().expect("just loaded"))
}

/// Stamp the staleness watermark onto a response: which generation
/// answered, where the source is, and whether the answer predates the
/// source's latest lineage swap.
fn watermark(out: &mut Json, replica: &Replica) {
    match replica.generation() {
        Some(g) => out.set("generation", g),
        None => out.set("generation", Json::Null),
    };
    match replica.source_generation() {
        Ok(src) => {
            let lag = match replica.generation() {
                Some(g) => src.saturating_sub(g),
                None => src + 1,
            };
            out.set("source_generation", src)
                .set("lag", lag)
                .set("stale", lag > 0);
        }
        Err(_) => {
            out.set("source_generation", Json::Null)
                .set("lag", Json::Null)
                .set("stale", true);
        }
    }
}

/// Execute one replica op (exposed for tests without sockets).
pub fn dispatch_replica(line: &str, ctx: &ReplicaCtx<'_>) -> Json {
    match dispatch_inner(line, ctx) {
        Ok(j) => j,
        Err(e) => {
            let mut j = Json::obj();
            j.set("ok", false).set("error", format!("{e:#}"));
            j
        }
    }
}

fn dispatch_inner(line: &str, ctx: &ReplicaCtx<'_>) -> anyhow::Result<Json> {
    // hot path: lazy scans over the raw bytes, like both admin planes
    let b = line.as_bytes();
    let op = json_scan::scan_str(b, "op")
        .map_err(scan_err)?
        .ok_or_else(|| anyhow::anyhow!("missing op"))?;
    let mut out = Json::obj();
    match op.as_ref() {
        "replica_status" => {
            let serving = ctx
                .serving
                .lock()
                .map_err(|_| anyhow::anyhow!("replica lock poisoned"))?;
            out = serving.replica.status_json();
            match &serving.state {
                Some(st) => out.set("serving_step", st.step),
                None => out.set("serving_step", Json::Null),
            };
            out.set("ok", true);
        }
        "sync" => {
            let mut serving = ctx
                .serving
                .lock()
                .map_err(|_| anyhow::anyhow!("replica lock poisoned"))?;
            let stats = serving.replica.sync()?;
            if !stats.already_current {
                // invalidate: the next query reloads from the adopted
                // generation
                serving.state = None;
            }
            out.set("ok", true).set("sync", stats.to_json());
        }
        "eval" => {
            let ids = json_scan::scan_u64s(b, "ids")
                .map_err(scan_err)?
                .ok_or_else(|| anyhow::anyhow!("eval needs ids"))?;
            anyhow::ensure!(!ids.is_empty(), "eval needs a non-empty ids list");
            let mut serving = ctx
                .serving
                .lock()
                .map_err(|_| anyhow::anyhow!("replica lock poisoned"))?;
            let st = ensure_loaded(&mut serving)?;
            let lc = per_example_loss_counts(
                ctx.rt,
                ModelView::Base(&st.state.params),
                &ctx.corpus,
                &ids,
            )?;
            let mut rows = Vec::with_capacity(ids.len());
            for (&id, (l, c)) in ids.iter().zip(lc) {
                let mut r = Json::obj();
                r.set("id", id).set("loss", l).set("count", c);
                rows.push(r);
            }
            out.set("ok", true)
                .set("serving_step", st.step)
                .set("results", Json::Arr(rows));
            watermark(&mut out, &serving.replica);
        }
        "loss" => {
            let id = json_scan::scan_u64(b, "id")
                .map_err(scan_err)?
                .ok_or_else(|| anyhow::anyhow!("loss needs id"))?;
            let mut serving = ctx
                .serving
                .lock()
                .map_err(|_| anyhow::anyhow!("replica lock poisoned"))?;
            let st = ensure_loaded(&mut serving)?;
            let lc = per_example_loss_counts(
                ctx.rt,
                ModelView::Base(&st.state.params),
                &ctx.corpus,
                &[id],
            )?;
            out.set("ok", true)
                .set("id", id)
                .set("loss", lc[0].0)
                .set("count", lc[0].1)
                .set("serving_step", st.step);
            watermark(&mut out, &serving.replica);
        }
        "shutdown" => {
            ctx.shutdown
                .store(true, std::sync::atomic::Ordering::SeqCst);
            out.set("ok", true).set("shutting_down", true);
        }
        other => anyhow::bail!("unknown replica op {other:?}"),
    }
    Ok(out)
}

/// Serve one replica's query plane on `addr` until a shutdown op
/// arrives.  Rides the shared nonblocking event loop, so transport
/// hardening (line cap, bounded flush, stall eviction) cannot drift
/// from the admin planes.
pub fn serve_replica(ctx: &ReplicaCtx<'_>, addr: &str) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("unlearn replica query server listening on {local}");
    crate::server::serve_event_loop(listener, &ctx.shutdown, |line| {
        dispatch_replica(line, ctx)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;

    fn mk_state(fill: f32, step: u32) -> TrainState {
        let mut s = TrainState::zeros_like(vec![fill; 8]);
        s.logical_step = step;
        s.applied_updates = step;
        s
    }

    /// Build a source store with two full checkpoints in gen 0.
    fn source_store(root: &std::path::Path) -> CheckpointStore {
        let store = CheckpointStore::open(root, 16).expect("open source");
        store.save_full(&mk_state(0.25, 4)).expect("save 4");
        store.save_full(&mk_state(0.5, 8)).expect("save 8");
        store
    }

    #[test]
    fn cold_sync_is_bit_identical() {
        let src = tempdir("replica-cold-src");
        let dst = tempdir("replica-cold-dst");
        let store = source_store(&src);
        let mut r = Replica::open(&src, &dst).expect("open replica");
        assert_eq!(r.generation(), None);
        assert!(r.load_serving_state().is_err(), "unsynced must refuse");
        let stats = r.sync().expect("cold sync");
        assert!(!stats.already_current);
        assert!(stats.objects_pulled > 0 && stats.bytes_pulled > 0);
        let served = r.load_serving_state().expect("serving state");
        assert_eq!(served.step, 8);
        assert!(served.state.bits_equal(&store.load_full(8).unwrap()));
        assert_eq!(r.lag().unwrap(), 0);
    }

    #[test]
    fn resync_after_swap_ships_only_new_objects() {
        let src = tempdir("replica-dedup-src");
        let dst = tempdir("replica-dedup-dst");
        let store = source_store(&src);
        let mut r = Replica::open(&src, &dst).expect("open replica");
        let cold = r.sync().expect("cold sync");
        // a repeat sync at the same generation moves nothing
        let again = r.sync().expect("noop sync");
        assert!(again.already_current);
        assert_eq!(again.bytes_pulled, 0);
        // launder-shaped swap: adopt step 4 untouched, rewrite step 8
        let stage = store.begin_lineage().expect("stage");
        stage.adopt_full(4).expect("adopt 4");
        stage.save_full(&mk_state(0.75, 8)).expect("rewrite 8");
        stage.commit(&[7], 8, 0).expect("commit");
        let warm = r.sync().expect("warm sync");
        assert!(!warm.already_current);
        assert_eq!(warm.to_generation, 1);
        // the dedup bound: strictly fewer bytes than the cold mirror,
        // and the shared step-4 blobs were reused, not re-shipped
        assert!(warm.bytes_pulled < cold.bytes_pulled);
        assert!(warm.objects_reused > 0);
        let served = r.load_serving_state().expect("post-swap state");
        assert_eq!(served.generation, 1);
        assert!(served.state.bits_equal(&store.load_full(8).unwrap()));
    }

    #[test]
    fn staleness_is_watermarked_until_resync() {
        let src = tempdir("replica-stale-src");
        let dst = tempdir("replica-stale-dst");
        let store = source_store(&src);
        let mut r = Replica::open(&src, &dst).expect("open replica");
        r.sync().expect("cold sync");
        let stage = store.begin_lineage().expect("stage");
        stage.adopt_full(8).expect("adopt 8");
        stage.commit(&[3], 8, 0).expect("commit");
        assert_eq!(r.lag().unwrap(), 1, "behind after the source swap");
        let j = r.status_json();
        assert_eq!(j.get("stale").and_then(|v| v.as_bool()), Some(true));
        r.sync().expect("resync");
        assert_eq!(r.lag().unwrap(), 0);
        let j = r.status_json();
        assert_eq!(j.get("stale").and_then(|v| v.as_bool()), Some(false));
    }

    #[test]
    fn corrupt_source_object_is_refused() {
        let src = tempdir("replica-corrupt-src");
        let dst = tempdir("replica-corrupt-dst");
        let store = source_store(&src);
        // flip bytes inside one referenced object, keeping its name
        let hashes = crate::checkpoint::state_tensor_hashes(
            &store.load_full(8).unwrap(),
        );
        let victim = {
            let mut v: Vec<String> = hashes.into_iter().collect();
            v.sort();
            v.remove(0)
        };
        std::fs::write(
            src.join("objects").join(&victim),
            vec![0xABu8; 32],
        )
        .expect("corrupt blob");
        let mut r = Replica::open(&src, &dst).expect("open replica");
        let err = r.sync().expect_err("sync must fail closed");
        assert!(
            format!("{err:#}").contains("refusing"),
            "unexpected error: {err:#}"
        );
        // nothing was adopted: the mirror still refuses to serve
        assert_eq!(r.generation(), None);
        assert!(r.load_serving_state().is_err());
    }
}
