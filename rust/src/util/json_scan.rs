//! Zero-alloc lazy JSON path extraction over raw line bytes.
//!
//! The admin planes (`server`, `fleet::server`) receive one JSON object
//! per line and, on the hot ops (`submit`/`poll`/`status`), need at most
//! a handful of top-level fields.  Building a full [`crate::util::json`]
//! tree per request allocates a `BTreeMap` plus a `String`/`Vec` per
//! node just to read two keys and throw the rest away.  This module is
//! a *visiting lexer*: it walks the raw bytes once, validating the
//! whole document, and records only the span of the requested top-level
//! key's value — no tree, and (for unescaped strings) no allocation at
//! all (`Cow::Borrowed`).
//!
//! ## Equivalence contract
//!
//! Every scanner below is **byte-equivalent** to the tree path it
//! replaces: for any input bytes `b`,
//!
//! * `scan_*(b, k)` errors **iff** `json::parse(str::from_utf8(b)?)`
//!   errors (same acceptance of escapes, numbers, nesting, duplicate
//!   keys, trailing garbage, truncation), and
//! * on success, `scan_str(b, k) == tree.get(k).and_then(as_str)`,
//!   `scan_u64(b, k) == tree.get(k).and_then(as_u64)` (including the
//!   `f64 as u64` saturating-cast semantics), and `scan_u64s` matches
//!   `as_arr` + `filter_map(as_u64)`.
//!
//! The contract is enforced by the adversarial property test at the
//! bottom of this file, which fuzzes well-formed and mutilated
//! documents against the tree parser: truncation must yield a typed
//! error on both sides, never a divergent value.
//!
//! To keep the mirror auditable, the lexer methods below are structured
//! one-to-one with `json.rs::Parser::{value,lit,number,string,array,
//! object}` — same acceptance checks, same boundary arithmetic, same
//! replacement-character and saturating-cast behavior.

use std::borrow::Cow;
use std::fmt;

/// Typed refusal from the scanner: byte offset reached plus reason.
/// Matches the *class* of `json::parse` errors (any malformed document
/// is refused); exact messages are not part of the wire contract.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScanError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} at byte {}", self.msg, self.at)
    }
}

impl std::error::Error for ScanError {}

/// Classified value just past the cursor, with enough span information
/// to extract it lazily.  Strings carry the *inner* span (between the
/// quotes) plus whether any escape sequence occurred — the unescaped
/// form only materializes when a caller actually asks for that string.
#[derive(Debug, Clone, Copy)]
enum Kind {
    Str { start: usize, end: usize, esc: bool },
    Num { start: usize, end: usize },
    Arr,
    /// Object / bool / null — the typed getters all answer `None` for
    /// these, matching the tree accessors.
    Other,
}

/// A located top-level value: its classification plus the full raw
/// byte span (used by [`scan_raw`] and the array re-walk).
#[derive(Debug, Clone, Copy)]
struct Hit {
    kind: Kind,
    start: usize,
    end: usize,
}

struct Scan<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\n' | b'\t' | b'\r')) {
            self.pos += 1;
        }
    }

    fn err(&self, msg: impl Into<String>) -> ScanError {
        ScanError { at: self.pos, msg: msg.into() }
    }

    fn expect(&mut self, c: u8) -> Result<(), ScanError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!(
                "expected {:?}, got {:?}",
                c as char,
                self.peek().map(|b| b as char)
            )))
        }
    }

    /// Mirror of `Parser::value` — dispatch on the first non-ws byte.
    fn value(&mut self) -> Result<Kind, ScanError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => {
                self.object()?;
                Ok(Kind::Other)
            }
            Some(b'[') => {
                self.array()?;
                Ok(Kind::Arr)
            }
            Some(b'"') => {
                let (start, end, esc) = self.string_span()?;
                Ok(Kind::Str { start, end, esc })
            }
            Some(b't') => self.lit("true"),
            Some(b'f') => self.lit("false"),
            Some(b'n') => self.lit("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let (start, end) = self.number()?;
                Ok(Kind::Num { start, end })
            }
            other => Err(self.err(format!(
                "unexpected {:?}",
                other.map(|b| b as char)
            ))),
        }
    }

    fn lit(&mut self, word: &str) -> Result<Kind, ScanError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(Kind::Other)
        } else {
            Err(self.err(format!("expected literal {word}")))
        }
    }

    /// Mirror of `Parser::number`: greedy lex of `-`/digits/`.eE+-`,
    /// then the span must satisfy `str::parse::<f64>()`.
    fn number(&mut self) -> Result<(usize, usize), ScanError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.pos])
            .expect("number span is ASCII");
        if s.parse::<f64>().is_err() {
            return Err(ScanError {
                at: start,
                msg: format!("bad number {s:?}"),
            });
        }
        Ok((start, self.pos))
    }

    /// Mirror of `Parser::string`, recording the inner span instead of
    /// materializing.  Validation is identical: same escape set, same
    /// `\u` boundary check and hex parse, and the whole inner span must
    /// be valid UTF-8 (escape sequences are pure ASCII, so whole-span
    /// validity is equivalent to the tree parser's piecewise checks).
    fn string_span(&mut self) -> Result<(usize, usize, bool), ScanError> {
        self.expect(b'"')?;
        let start = self.pos;
        let mut esc = false;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let end = self.pos;
                    self.pos += 1;
                    if std::str::from_utf8(&self.b[start..end]).is_err() {
                        return Err(ScanError {
                            at: start,
                            msg: "invalid utf-8 in string".into(),
                        });
                    }
                    return Ok((start, end, esc));
                }
                Some(b'\\') => {
                    esc = true;
                    self.pos += 1;
                    match self.peek() {
                        Some(
                            b'"' | b'\\' | b'/' | b'n' | b't' | b'r' | b'b'
                            | b'f',
                        ) => {}
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            if u32::from_str_radix(hex, 16).is_err() {
                                return Err(self.err("bad \\u escape"));
                            }
                            self.pos += 4;
                        }
                        other => {
                            return Err(self.err(format!(
                                "bad escape {:?}",
                                other.map(|b| b as char)
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    fn array(&mut self) -> Result<(), ScanError> {
        self.expect(b'[')?;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.value()?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(());
                }
                other => {
                    return Err(self.err(format!(
                        "expected ',' or ']', got {:?}",
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn object(&mut self) -> Result<(), ScanError> {
        self.expect(b'{')?;
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            self.string_span()?;
            self.skip_ws();
            self.expect(b':')?;
            self.value()?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(());
                }
                other => {
                    return Err(self.err(format!(
                        "expected ',' or '}}', got {:?}",
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }
}

/// Decode a validated inner string span into an owned `String`,
/// byte-for-byte like `Parser::string` (same escape table, same
/// `char::from_u32(..).unwrap_or(U+FFFD)` for unpaired surrogates).
fn unescape(raw: &[u8]) -> String {
    let mut out = String::with_capacity(raw.len());
    let mut i = 0;
    while i < raw.len() {
        if raw[i] == b'\\' {
            i += 1;
            match raw[i] {
                b'"' => out.push('"'),
                b'\\' => out.push('\\'),
                b'/' => out.push('/'),
                b'n' => out.push('\n'),
                b't' => out.push('\t'),
                b'r' => out.push('\r'),
                b'b' => out.push('\u{8}'),
                b'f' => out.push('\u{c}'),
                b'u' => {
                    let hex = std::str::from_utf8(&raw[i + 1..i + 5])
                        .expect("validated hex span");
                    let cp = u32::from_str_radix(hex, 16)
                        .expect("validated hex span");
                    out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    i += 4;
                }
                _ => unreachable!("span validated by string_span"),
            }
            i += 1;
        } else {
            let rest = std::str::from_utf8(&raw[i..])
                .expect("span validated by string_span");
            let ch = rest.chars().next().expect("non-empty rest");
            out.push(ch);
            i += ch.len_utf8();
        }
    }
    out
}

fn key_matches(raw: &[u8], esc: bool, key: &str) -> bool {
    if !esc {
        raw == key.as_bytes()
    } else {
        unescape(raw) == key
    }
}

/// Validate the whole document and locate the top-level `key`'s value.
/// Duplicate keys: the **last** occurrence wins, matching the tree
/// parser's `BTreeMap::insert`.  A non-object top level validates but
/// yields no hit (the tree path's `get` on a non-object is `None`).
fn find_top(b: &[u8], key: &str) -> Result<Option<Hit>, ScanError> {
    let mut s = Scan { b, pos: 0 };
    s.skip_ws();
    let mut hit = None;
    if s.peek() == Some(b'{') {
        s.pos += 1;
        s.skip_ws();
        if s.peek() == Some(b'}') {
            s.pos += 1;
        } else {
            loop {
                s.skip_ws();
                let (ks, ke, kesc) = s.string_span()?;
                s.skip_ws();
                s.expect(b':')?;
                s.skip_ws();
                let vstart = s.pos;
                let kind = s.value()?;
                if key_matches(&b[ks..ke], kesc, key) {
                    hit = Some(Hit { kind, start: vstart, end: s.pos });
                }
                s.skip_ws();
                match s.peek() {
                    Some(b',') => s.pos += 1,
                    Some(b'}') => {
                        s.pos += 1;
                        break;
                    }
                    other => {
                        return Err(s.err(format!(
                            "expected ',' or '}}', got {:?}",
                            other.map(|c| c as char)
                        )))
                    }
                }
            }
        }
    } else {
        s.value()?;
    }
    s.skip_ws();
    if s.pos != b.len() {
        return Err(s.err("trailing garbage"));
    }
    Ok(hit)
}

/// Validate `b` as one JSON document (accepts exactly what
/// `json::parse` accepts; no value is materialized).
pub fn validate(b: &[u8]) -> Result<(), ScanError> {
    let mut s = Scan { b, pos: 0 };
    s.value()?;
    s.skip_ws();
    if s.pos != b.len() {
        return Err(s.err("trailing garbage"));
    }
    Ok(())
}

/// `tree.get(key).and_then(as_str)` without the tree.  Unescaped
/// strings borrow straight from `b` (zero-alloc hot path).
pub fn scan_str<'a>(
    b: &'a [u8],
    key: &str,
) -> Result<Option<Cow<'a, str>>, ScanError> {
    Ok(match find_top(b, key)? {
        Some(Hit { kind: Kind::Str { start, end, esc }, .. }) => {
            let raw = &b[start..end];
            Some(if esc {
                Cow::Owned(unescape(raw))
            } else {
                Cow::Borrowed(
                    std::str::from_utf8(raw).expect("span validated"),
                )
            })
        }
        _ => None,
    })
}

/// `tree.get(key).and_then(as_u64)` without the tree — including the
/// tree path's `f64 as u64` saturating cast (negatives and NaN → 0,
/// overflow → `u64::MAX`).
pub fn scan_u64(b: &[u8], key: &str) -> Result<Option<u64>, ScanError> {
    Ok(match find_top(b, key)? {
        Some(Hit { kind: Kind::Num { start, end }, .. }) => {
            let s = std::str::from_utf8(&b[start..end])
                .expect("number span is ASCII");
            let f: f64 = s.parse().expect("span validated");
            Some(f as u64)
        }
        _ => None,
    })
}

/// `tree.get(key).and_then(as_arr)` + `filter_map(as_u64)` without the
/// tree: numeric elements collected, everything else skipped.
pub fn scan_u64s(
    b: &[u8],
    key: &str,
) -> Result<Option<Vec<u64>>, ScanError> {
    let hit = match find_top(b, key)? {
        Some(h @ Hit { kind: Kind::Arr, .. }) => h,
        _ => return Ok(None),
    };
    // Re-walk the already-validated array span, keeping number elements.
    let mut s = Scan { b, pos: hit.start };
    s.expect(b'[').expect("span validated");
    let mut out = Vec::new();
    s.skip_ws();
    if s.peek() == Some(b']') {
        return Ok(Some(out));
    }
    loop {
        let kind = s.value().expect("span validated");
        if let Kind::Num { start, end } = kind {
            let f: f64 = std::str::from_utf8(&b[start..end])
                .expect("number span is ASCII")
                .parse()
                .expect("span validated");
            out.push(f as u64);
        }
        s.skip_ws();
        match s.peek() {
            Some(b',') => s.pos += 1,
            _ => break, // validated span: must be ']'
        }
    }
    Ok(Some(out))
}

/// Raw byte span of `key`'s value (any kind), with the whole document
/// validated.  The span is itself a valid standalone document, so
/// nested payloads decode with further scans instead of a tree.
pub fn scan_raw<'a>(
    b: &'a [u8],
    key: &str,
) -> Result<Option<&'a [u8]>, ScanError> {
    Ok(find_top(b, key)?.map(|h| &b[h.start..h.end]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{parse, Json};
    use crate::util::prop::for_all;
    use crate::util::rng::SplitMix64;

    #[test]
    fn extracts_hot_submit_fields_without_tree() {
        let line = br#"{"op":"submit","id":"req-1","user":42,"sample_ids":[3,1,2],"urgency":"high"}"#;
        assert_eq!(scan_str(line, "op").unwrap().as_deref(), Some("submit"));
        assert_eq!(scan_str(line, "id").unwrap().as_deref(), Some("req-1"));
        assert_eq!(scan_u64(line, "user").unwrap(), Some(42));
        assert_eq!(
            scan_u64s(line, "sample_ids").unwrap(),
            Some(vec![3, 1, 2])
        );
        assert_eq!(
            scan_str(line, "urgency").unwrap().as_deref(),
            Some("high")
        );
        assert_eq!(scan_str(line, "missing").unwrap(), None);
    }

    #[test]
    fn unescaped_strings_borrow() {
        let line = br#"{"op":"status"}"#;
        match scan_str(line, "op").unwrap() {
            Some(Cow::Borrowed(s)) => assert_eq!(s, "status"),
            other => panic!("expected borrowed str, got {other:?}"),
        }
    }

    #[test]
    fn escaped_keys_and_values_match_tree() {
        let line = br#"{"op":"a\nb","x":"\ud800"}"#;
        let tree = parse(std::str::from_utf8(line).unwrap()).unwrap();
        assert_eq!(
            scan_str(line, "op").unwrap().as_deref(),
            tree.get("op").and_then(Json::as_str)
        );
        // unpaired surrogate → U+FFFD on both sides
        assert_eq!(
            scan_str(line, "x").unwrap().as_deref(),
            tree.get("x").and_then(Json::as_str)
        );
    }

    #[test]
    fn duplicate_keys_last_wins_like_btreemap() {
        let line = br#"{"op":"first","op":"second"}"#;
        let tree = parse(std::str::from_utf8(line).unwrap()).unwrap();
        assert_eq!(tree.get("op").and_then(Json::as_str), Some("second"));
        assert_eq!(scan_str(line, "op").unwrap().as_deref(), Some("second"));
    }

    #[test]
    fn wrong_type_is_none_not_error() {
        let line = br#"{"op":3,"job":"j","n":true}"#;
        assert_eq!(scan_str(line, "op").unwrap(), None);
        assert_eq!(scan_u64(line, "op").unwrap(), Some(3));
        assert_eq!(scan_u64(line, "job").unwrap(), None);
        assert_eq!(scan_u64(line, "n").unwrap(), None);
        assert_eq!(scan_u64s(line, "op").unwrap(), None);
    }

    #[test]
    fn saturating_cast_matches_tree() {
        for line in [
            br#"{"user":-3}"#.as_slice(),
            br#"{"user":1e300}"#,
            br#"{"user":2.9}"#,
        ] {
            let tree = parse(std::str::from_utf8(line).unwrap()).unwrap();
            assert_eq!(
                scan_u64(line, "user").unwrap(),
                tree.get("user").and_then(Json::as_u64),
            );
        }
    }

    #[test]
    fn truncation_is_typed_error_never_a_value() {
        for line in [
            br#"{"op":"sub"#.as_slice(),
            br#"{"op""#,
            br#"{"op":"#,
            br#"{"op":"x",}"#,
            br#"{"op":"x"} extra"#,
            br#"{"op":1e}"#,
            br#"{"op":"\u00"#,
            b"",
        ] {
            assert!(scan_str(line, "op").is_err(), "accepted {line:?}");
            assert!(
                parse(&String::from_utf8_lossy(line)).is_err(),
                "tree accepted {line:?}"
            );
        }
    }

    #[test]
    fn non_object_top_level_validates_to_none() {
        assert_eq!(scan_str(b"[1,2,3]", "op").unwrap(), None);
        assert_eq!(scan_str(b"42", "op").unwrap(), None);
        assert_eq!(scan_str(b"null", "op").unwrap(), None);
        assert!(validate(b"[1,{\"a\":[true,null]},\"x\"]").is_ok());
    }

    #[test]
    fn scan_raw_yields_standalone_document() {
        let line = br#"{"event":"submit","request":{"id":"r","user":7}}"#;
        let raw = scan_raw(line, "request").unwrap().unwrap();
        assert_eq!(scan_str(raw, "id").unwrap().as_deref(), Some("r"));
        assert_eq!(scan_u64(raw, "user").unwrap(), Some(7));
    }

    // ---- adversarial equivalence property ----------------------------

    const KEYS: &[&str] = &["op", "id", "user", "ids", "dup", "k\"q", "é"];

    fn gen_string(r: &mut SplitMix64) -> String {
        let pieces = [
            "a", "xyz", "", "é", "日", "\\n", "\\t", "\\\\", "\\\"",
            "\\/", "\\u0041", "\\u00e9", "\\ud800", "\\uffff", " ", "0",
            "{", "[", ",", ":",
        ];
        let n = r.below(4);
        let mut s = String::new();
        for _ in 0..n {
            s.push_str(pieces[r.below(pieces.len() as u64) as usize]);
        }
        s
    }

    fn gen_number(r: &mut SplitMix64) -> &'static str {
        let nums = [
            "0", "-0", "7", "42", "1.5", "-2.75e-3", "3e8", "1e309",
            "-1e309", "18446744073709551616", "0.0001",
        ];
        nums[r.below(nums.len() as u64) as usize]
    }

    fn gen_value(r: &mut SplitMix64, depth: u32, out: &mut String) {
        match if depth == 0 { r.below(5) } else { r.below(7) } {
            0 => {
                out.push('"');
                out.push_str(&gen_string(r));
                out.push('"');
            }
            1 => out.push_str(gen_number(r)),
            2 => out.push_str("true"),
            3 => out.push_str("false"),
            4 => out.push_str("null"),
            5 => {
                out.push('[');
                let n = r.below(4);
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    gen_value(r, depth - 1, out);
                }
                out.push(']');
            }
            _ => {
                out.push('{');
                let n = r.below(3);
                for i in 0..n {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&gen_string(r));
                    out.push_str("\":");
                    gen_value(r, depth - 1, out);
                }
                out.push('}');
            }
        }
    }

    /// Top-level object drawing keys from the fixed pool so lookups hit,
    /// duplicates occur, and values span every kind.
    fn gen_doc(r: &mut SplitMix64) -> String {
        let mut s = String::from("{");
        let n = r.below(6);
        for i in 0..n {
            if i > 0 {
                s.push(',');
            }
            s.push('"');
            let k = KEYS[r.below(KEYS.len() as u64) as usize];
            if k == "k\"q" {
                s.push_str("k\\\"q");
            } else if k == "op" && r.below(8) == 0 {
                s.push_str("o\\u0070"); // escaped spelling of "op"
            } else {
                s.push_str(k);
            }
            s.push_str("\": ");
            gen_value(r, 2, &mut s);
        }
        s.push('}');
        s
    }

    fn mutilate(r: &mut SplitMix64, doc: &str) -> String {
        let idxs: Vec<usize> =
            doc.char_indices().map(|(i, _)| i).collect();
        if idxs.is_empty() {
            return "x".into();
        }
        let cut = idxs[r.below(idxs.len() as u64) as usize];
        match r.below(4) {
            0 => doc[..cut].to_string(),
            1 => format!("{doc}x"),
            2 => format!("{}]{}", &doc[..cut], &doc[cut..]),
            _ => format!("{},{}", &doc[..cut], &doc[cut..]),
        }
    }

    #[test]
    fn prop_scan_agrees_with_tree_parser_on_adversarial_docs() {
        for_all("json_scan_vs_tree", |r| {
            let mut doc = gen_doc(r);
            if r.below(3) == 0 {
                doc = mutilate(r, &doc);
            }
            let b = doc.as_bytes();
            let tree = parse(&doc);
            assert_eq!(
                validate(b).is_ok(),
                tree.is_ok(),
                "acceptance diverged on {doc:?}: scan={:?} tree={:?}",
                validate(b),
                tree.as_ref().err(),
            );
            for key in KEYS {
                let s = scan_str(b, key);
                let u = scan_u64(b, key);
                let a = scan_u64s(b, key);
                match &tree {
                    Err(_) => {
                        assert!(s.is_err(), "scan_str accepted {doc:?}");
                        assert!(u.is_err(), "scan_u64 accepted {doc:?}");
                        assert!(a.is_err(), "scan_u64s accepted {doc:?}");
                    }
                    Ok(t) => {
                        assert_eq!(
                            s.unwrap().as_deref(),
                            t.get(key).and_then(Json::as_str),
                            "scan_str({key:?}) diverged on {doc:?}"
                        );
                        assert_eq!(
                            u.unwrap(),
                            t.get(key).and_then(Json::as_u64),
                            "scan_u64({key:?}) diverged on {doc:?}"
                        );
                        let want = t.get(key).and_then(Json::as_arr).map(
                            |xs| {
                                xs.iter()
                                    .filter_map(Json::as_u64)
                                    .collect::<Vec<u64>>()
                            },
                        );
                        assert_eq!(
                            a.unwrap(),
                            want,
                            "scan_u64s({key:?}) diverged on {doc:?}"
                        );
                    }
                }
            }
        });
    }
}
