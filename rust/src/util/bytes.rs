//! Exact byte-level views of f32 tensors.
//!
//! Everything the paper's exactness story touches — checkpoints, XOR
//! patches, state hashes — must operate on the *raw dtype bit patterns*
//! (G3a).  These helpers define the conversion between `f32` vectors
//! and little-endian byte streams exactly once; the hot paths go
//! through the zero-copy views and word-wise scans in [`super::simd`]
//! instead of materializing serialized copies.

use super::simd;

/// f32 slice -> little-endian bytes (owned copy).  Hot paths should use
/// [`simd::as_bytes`] instead — this allocates.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    simd::as_bytes(v).to_vec()
}

/// Little-endian bytes -> f32 vector.  Errors if length is not 4-aligned.
pub fn bytes_to_f32s(b: &[u8]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(b.len() % 4 == 0, "byte length {} not 4-aligned", b.len());
    let mut out = vec![0.0f32; b.len() / 4];
    simd::as_bytes_mut(&mut out).copy_from_slice(b);
    Ok(out)
}

/// Bit-pattern equality of two f32 slices (NaN-safe, -0.0 != +0.0):
/// the "bit-identical in training dtype" relation of G1.  Word-wise
/// (memcmp) over the raw byte images.
pub fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && simd::bytes_equal(simd::as_bytes(a), simd::as_bytes(b))
}

/// First index where bit patterns differ (diagnostics for CI-gate output).
pub fn first_bit_mismatch(a: &[f32], b: &[f32]) -> Option<usize> {
    if a.len() != b.len() {
        return Some(a.len().min(b.len()));
    }
    simd::first_mismatch(simd::as_bytes(a), simd::as_bytes(b)).map(|i| i / 4)
}

/// Max |a - b| (diagnostics; Table 4 reports this for the inexact regime).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        // detlint: allow(float-reduce) — max is order-insensitive and this
        // is a diagnostic; no serialized state depends on it
        .fold(0.0f32, f32::max)
}

/// XOR two byte slices elementwise into a fresh vector (G3a patches).
/// Fails closed on length mismatch — mismatched patches can arrive from
/// corrupt ring/disk state and must never partially apply.
pub fn xor_bytes(a: &[u8], b: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut out = Vec::new();
    simd::xor_into(&mut out, a, b)?;
    Ok(out)
}

/// In-place XOR: `dst ^= src` (word-wise).  Fails closed on length
/// mismatch.
pub fn xor_in_place(dst: &mut [u8], src: &[u8]) -> anyhow::Result<()> {
    simd::xor_in_place(dst, src)
}

/// Content hash of an f32 tensor state (the Table 5 model/optimizer
/// hashes): SHA-256 over the LE byte image (zero-copy view), truncated
/// to 64 bits and hex-encoded like the paper's `82c10410...b978339c`
/// style.
pub fn state_hash64(v: &[f32]) -> String {
    let h = super::hashing::sha256(simd::as_bytes(v));
    super::hashing::hex(&h[..8])
}

/// Full SHA-256 content hash of an f32 tensor state.
pub fn state_hash_full(v: &[f32]) -> String {
    super::hashing::sha256_hex(simd::as_bytes(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_bits() {
        let v = vec![
            0.0f32,
            -0.0,
            1.5,
            f32::NAN,
            f32::INFINITY,
            f32::MIN_POSITIVE,
            -123.456,
            f32::from_bits(0x7f800001), // signaling NaN pattern
        ];
        let b = f32s_to_bytes(&v);
        let back = bytes_to_f32s(&b).unwrap();
        assert!(bits_equal(&v, &back));
    }

    #[test]
    fn bits_equal_distinguishes_zero_signs() {
        assert!(!bits_equal(&[0.0], &[-0.0]));
        assert!(bits_equal(&[f32::NAN], &[f32::NAN]));
    }

    #[test]
    fn xor_is_involution() {
        let a: Vec<u8> = (0..=255).collect();
        let b: Vec<u8> = (0..=255).rev().collect();
        let patch = xor_bytes(&a, &b).unwrap();
        let mut restored = b.clone();
        xor_in_place(&mut restored, &patch).unwrap();
        assert_eq!(restored, a);
    }

    #[test]
    fn xor_length_mismatch_is_an_error_not_a_panic() {
        assert!(xor_bytes(&[1, 2], &[1, 2, 3]).is_err());
        let mut d = vec![0u8; 2];
        assert!(xor_in_place(&mut d, &[0u8; 3]).is_err());
    }

    #[test]
    fn mismatch_index() {
        let a = vec![1.0f32, 2.0, 3.0];
        let mut b = a.clone();
        assert_eq!(first_bit_mismatch(&a, &b), None);
        b[1] = f32::from_bits(b[1].to_bits() ^ 1); // single-ULP flip
        assert_eq!(first_bit_mismatch(&a, &b), Some(1));
    }

    #[test]
    fn state_hash_is_stable_and_sensitive() {
        let v = vec![1.0f32; 100];
        assert_eq!(state_hash64(&v), state_hash64(&v));
        let mut w = v.clone();
        w[99] = f32::from_bits(v[99].to_bits() ^ 1); // single-ULP flip
        assert_ne!(state_hash64(&v), state_hash64(&w));
        assert_eq!(state_hash64(&v).len(), 16);
    }

    #[test]
    fn rejects_unaligned() {
        assert!(bytes_to_f32s(&[0, 1, 2]).is_err());
    }
}
