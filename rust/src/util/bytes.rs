//! Exact byte-level views of f32 tensors.
//!
//! Everything the paper's exactness story touches — checkpoints, XOR
//! patches, state hashes — must operate on the *raw dtype bit patterns*
//! (G3a).  These helpers are the only place we convert between `f32`
//! vectors and little-endian byte streams, so the representation is
//! defined exactly once.

/// f32 slice -> little-endian bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Little-endian bytes -> f32 vector.  Errors if length is not 4-aligned.
pub fn bytes_to_f32s(b: &[u8]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(b.len() % 4 == 0, "byte length {} not 4-aligned", b.len());
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Bit-pattern equality of two f32 slices (NaN-safe, -0.0 != +0.0):
/// the "bit-identical in training dtype" relation of G1.
pub fn bits_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// First index where bit patterns differ (diagnostics for CI-gate output).
pub fn first_bit_mismatch(a: &[f32], b: &[f32]) -> Option<usize> {
    if a.len() != b.len() {
        return Some(a.len().min(b.len()));
    }
    a.iter()
        .zip(b)
        .position(|(x, y)| x.to_bits() != y.to_bits())
}

/// Max |a - b| (diagnostics; Table 4 reports this for the inexact regime).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// XOR two byte slices elementwise into a fresh vector (G3a patches).
pub fn xor_bytes(a: &[u8], b: &[u8]) -> Vec<u8> {
    assert_eq!(a.len(), b.len(), "xor length mismatch");
    a.iter().zip(b).map(|(x, y)| x ^ y).collect()
}

/// In-place XOR: `dst ^= src`.
pub fn xor_in_place(dst: &mut [u8], src: &[u8]) {
    assert_eq!(dst.len(), src.len(), "xor length mismatch");
    for (d, s) in dst.iter_mut().zip(src) {
        *d ^= s;
    }
}

/// Content hash of an f32 tensor state (the Table 5 model/optimizer
/// hashes): SHA-256 over the LE byte image, truncated to 64 bits and
/// hex-encoded like the paper's `82c10410...b978339c` style.
pub fn state_hash64(v: &[f32]) -> String {
    let h = super::hashing::sha256(&f32s_to_bytes(v));
    super::hashing::hex(&h[..8])
}

/// Full SHA-256 content hash of an f32 tensor state.
pub fn state_hash_full(v: &[f32]) -> String {
    super::hashing::sha256_hex(&f32s_to_bytes(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_bits() {
        let v = vec![
            0.0f32,
            -0.0,
            1.5,
            f32::NAN,
            f32::INFINITY,
            f32::MIN_POSITIVE,
            -123.456,
            f32::from_bits(0x7f800001), // signaling NaN pattern
        ];
        let b = f32s_to_bytes(&v);
        let back = bytes_to_f32s(&b).unwrap();
        assert!(bits_equal(&v, &back));
    }

    #[test]
    fn bits_equal_distinguishes_zero_signs() {
        assert!(!bits_equal(&[0.0], &[-0.0]));
        assert!(bits_equal(&[f32::NAN], &[f32::NAN]));
    }

    #[test]
    fn xor_is_involution() {
        let a: Vec<u8> = (0..=255).collect();
        let b: Vec<u8> = (0..=255).rev().collect();
        let patch = xor_bytes(&a, &b);
        let mut restored = b.clone();
        xor_in_place(&mut restored, &patch);
        assert_eq!(restored, a);
    }

    #[test]
    fn mismatch_index() {
        let a = vec![1.0f32, 2.0, 3.0];
        let mut b = a.clone();
        assert_eq!(first_bit_mismatch(&a, &b), None);
        b[1] = f32::from_bits(b[1].to_bits() ^ 1); // single-ULP flip
        assert_eq!(first_bit_mismatch(&a, &b), Some(1));
    }

    #[test]
    fn state_hash_is_stable_and_sensitive() {
        let v = vec![1.0f32; 100];
        assert_eq!(state_hash64(&v), state_hash64(&v));
        let mut w = v.clone();
        w[99] = f32::from_bits(v[99].to_bits() ^ 1); // single-ULP flip
        assert_ne!(state_hash64(&v), state_hash64(&w));
        assert_eq!(state_hash64(&v).len(), 16);
    }

    #[test]
    fn rejects_unaligned() {
        assert!(bytes_to_f32s(&[0, 1, 2]).is_err());
    }
}
