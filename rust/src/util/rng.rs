//! Deterministic RNG substrate.
//!
//! Two generators, both counter-friendly and fully reproducible:
//! - [`SplitMix64`]: stream generator for corpus synthesis, shuffling and
//!   the property-test harness.
//! - [`philox_u64`]: a counter-based value function (keyed mixing of
//!   (seed, counter)) used wherever the paper requires *index-stable*
//!   stochasticity (Lemma A.2(i)): the draw for logical index `j` is a
//!   pure function of `(seed, j)` and never depends on neighbours.

/// SplitMix64 — tiny, high-quality sequential PRNG.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }

    /// Uniform in `[0, n)` (n > 0) via rejection-free multiply-shift.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fisher-Yates shuffle (deterministic given the generator state).
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Counter-based draw: value at `(seed, counter)` — index-stable by
/// construction (Lemma A.2(i)).  Implemented as a double SplitMix64 mix of
/// the keyed counter, which has the same pure-function property as Philox
/// at toy scale.
pub fn philox_u64(seed: u64, counter: u64) -> u64 {
    mix(mix(seed ^ 0xD6E8FEB86659FD93).wrapping_add(mix(counter)))
}

/// Per-microbatch seed bundle derivation (the WAL `seed64` field):
/// a pure function of (run_seed, logical step, microbatch index).
pub fn microbatch_seed(run_seed: u64, step: u32, mb_index: u32) -> u64 {
    philox_u64(run_seed, ((step as u64) << 32) | mb_index as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reproducible() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // SplitMix64(0) first outputs (reference values)
        let mut r = SplitMix64::new(0);
        assert_eq!(r.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(r.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = SplitMix64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(3);
        let n = 20000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn philox_index_stable() {
        // the draw at counter 5 is independent of any other counter query
        let direct = philox_u64(99, 5);
        let _ = philox_u64(99, 0);
        let _ = philox_u64(99, 123456);
        assert_eq!(philox_u64(99, 5), direct);
        assert_ne!(philox_u64(99, 5), philox_u64(99, 6));
        assert_ne!(philox_u64(99, 5), philox_u64(100, 5));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn microbatch_seed_unique_per_coords() {
        let mut seen = std::collections::HashSet::new();
        for step in 0..50 {
            for mb in 0..4 {
                assert!(seen.insert(microbatch_seed(1, step, mb)));
            }
        }
    }
}
