//! Zero-copy byte views and word-wise scans — the hot-path byte layer.
//!
//! Every exactness-critical hot path (XOR patches, state hashes, bit
//! equality, checkpoint I/O) operates on the raw f32 bit patterns.  The
//! seed implementation materialized a fresh `Vec<u8>` copy of every
//! tensor and then walked it one byte at a time; at ring-buffer rates
//! (3 parameter-sized tensors per optimizer step) those copies dominate
//! the deletion-latency budget.  This module provides:
//!
//! - [`as_bytes`] / [`as_bytes_mut`]: zero-copy `&[f32] <-> &[u8]`
//!   views (no allocation, no serialization pass);
//! - [`xor_in_place`] / [`xor_into`]: `u128`-word XOR (16 bytes per
//!   operation instead of 1);
//! - [`bytes_equal`] / [`first_mismatch`]: word-wise equality and
//!   first-difference scans.
//!
//! Bit-identity semantics are unchanged: [`scalar`] keeps the reference
//! byte-at-a-time implementations and the property tests below prove
//! byte-for-byte equivalence on adversarial inputs (NaN payloads, -0.0,
//! denormals, ±inf).
//!
//! The `&[f32] -> &[u8]` view is only an LE byte *image* on a
//! little-endian target, which is what the on-disk formats pin; the
//! compile-time assertion below refuses big-endian builds rather than
//! silently changing checkpoint bytes.

// The on-disk formats (checkpoints, WAL, delta frames) are defined as
// little-endian; a big-endian build would reinterpret them incorrectly.
const _: () = assert!(
    cfg!(target_endian = "little"),
    "unlearn requires a little-endian target: zero-copy f32 byte views \
     are defined as the LE byte image"
);

/// Zero-copy view of an f32 slice as its little-endian byte image.
///
/// Sound: `f32` has size 4, alignment 4, no padding, and every byte
/// pattern is a valid `u8`; narrowing alignment is always allowed.
#[inline]
pub fn as_bytes(v: &[f32]) -> &[u8] {
    // SAFETY: see doc comment — same allocation, length v.len()*4,
    // u8 has alignment 1 and no validity constraints.
    unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) }
}

/// Zero-copy mutable view of an f32 slice as its LE byte image.
///
/// Sound for writes too: every 4-byte pattern is a valid `f32` bit
/// pattern (signaling NaNs included — we never do arithmetic through
/// this view, only byte transport).
#[inline]
pub fn as_bytes_mut(v: &mut [f32]) -> &mut [u8] {
    // SAFETY: as in `as_bytes`; exclusive borrow is carried through.
    unsafe {
        std::slice::from_raw_parts_mut(v.as_mut_ptr().cast::<u8>(), v.len() * 4)
    }
}

/// `dst ^= src`, 16 bytes per word operation.  Fails closed on length
/// mismatch (corrupt patch metadata must never partially apply).
pub fn xor_in_place(dst: &mut [u8], src: &[u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        dst.len() == src.len(),
        "xor length mismatch: dst {} vs src {}",
        dst.len(),
        src.len()
    );
    let mut d = dst.chunks_exact_mut(16);
    let mut s = src.chunks_exact(16);
    for (dw, sw) in d.by_ref().zip(s.by_ref()) {
        let a = u128::from_le_bytes((&*dw).try_into().unwrap());
        let b = u128::from_le_bytes(sw.try_into().unwrap());
        dw.copy_from_slice(&(a ^ b).to_le_bytes());
    }
    for (db, sb) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *db ^= sb;
    }
    Ok(())
}

/// `out = a ^ b` into a caller-provided buffer (resized to fit) —
/// word-wise, no intermediate allocation beyond the reused buffer.
pub fn xor_into(out: &mut Vec<u8>, a: &[u8], b: &[u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.len() == b.len(),
        "xor length mismatch: {} vs {}",
        a.len(),
        b.len()
    );
    out.clear();
    out.resize(a.len(), 0);
    let mut o = out.chunks_exact_mut(16);
    let mut ia = a.chunks_exact(16);
    let mut ib = b.chunks_exact(16);
    for ((ow, aw), bw) in o.by_ref().zip(ia.by_ref()).zip(ib.by_ref()) {
        let x = u128::from_le_bytes(aw.try_into().unwrap());
        let y = u128::from_le_bytes(bw.try_into().unwrap());
        ow.copy_from_slice(&(x ^ y).to_le_bytes());
    }
    for ((ob, ab), bb) in o
        .into_remainder()
        .iter_mut()
        .zip(ia.remainder())
        .zip(ib.remainder())
    {
        *ob = ab ^ bb;
    }
    Ok(())
}

/// Byte equality (compiles to a memcmp — the word-wise fast path).
#[inline]
pub fn bytes_equal(a: &[u8], b: &[u8]) -> bool {
    a == b
}

/// Index of the first differing byte, scanning 8-byte words.
pub fn first_mismatch(a: &[u8], b: &[u8]) -> Option<usize> {
    if a.len() != b.len() {
        return Some(a.len().min(b.len()));
    }
    let words = a.len() / 8;
    for i in 0..words {
        let off = i * 8;
        let x = u64::from_le_bytes(a[off..off + 8].try_into().unwrap());
        let y = u64::from_le_bytes(b[off..off + 8].try_into().unwrap());
        if x != y {
            // LE: the lowest-order differing byte is the first in memory
            return Some(off + ((x ^ y).trailing_zeros() / 8) as usize);
        }
    }
    (words * 8..a.len()).find(|&i| a[i] != b[i])
}

/// Reference byte-at-a-time implementations.  These define the
/// semantics the word-wise paths must match bit-for-bit; kept public so
/// the benches can measure the before/after delta and the property
/// tests can assert equivalence.
pub mod scalar {
    /// One-byte-at-a-time XOR (the seed's hot-path implementation).
    pub fn xor_in_place(dst: &mut [u8], src: &[u8]) {
        assert_eq!(dst.len(), src.len());
        for (d, s) in dst.iter_mut().zip(src) {
            *d ^= s;
        }
    }

    /// Serializing f32 -> LE bytes with a fresh allocation per call.
    pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
        let mut out = Vec::with_capacity(v.len() * 4);
        for x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }

    /// Element-wise first-mismatch scan.
    pub fn first_mismatch(a: &[u8], b: &[u8]) -> Option<usize> {
        if a.len() != b.len() {
            return Some(a.len().min(b.len()));
        }
        a.iter().zip(b).position(|(x, y)| x != y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{byte_vec, f32_vec_adversarial, for_all};

    #[test]
    fn view_matches_serialized_bytes() {
        for_all("as_bytes == f32s_to_bytes", |rng| {
            let n = rng.below(500) as usize;
            let v = f32_vec_adversarial(rng, n);
            assert_eq!(as_bytes(&v), scalar::f32s_to_bytes(&v).as_slice());
        });
    }

    #[test]
    fn mut_view_roundtrips_bits() {
        let mut v = vec![1.5f32, f32::NAN, -0.0, f32::from_bits(0x7f800001)];
        let orig = v.clone();
        let snapshot: Vec<u8> = as_bytes(&v).to_vec();
        as_bytes_mut(&mut v).copy_from_slice(&snapshot);
        assert!(orig
            .iter()
            .zip(&v)
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn wordwise_xor_equals_scalar_xor() {
        for_all("xor word == xor byte", |rng| {
            let n = rng.below(200) as usize; // covers remainders < 16
            let a = byte_vec(rng, n);
            let b = byte_vec(rng, n);
            let mut fast = a.clone();
            xor_in_place(&mut fast, &b).unwrap();
            let mut slow = a.clone();
            scalar::xor_in_place(&mut slow, &b);
            assert_eq!(fast, slow);
        });
    }

    #[test]
    fn xor_into_equals_scalar() {
        for_all("xor_into == scalar", |rng| {
            let n = rng.below(100) as usize;
            let a = byte_vec(rng, n);
            let b = byte_vec(rng, n);
            let mut out = Vec::new();
            xor_into(&mut out, &a, &b).unwrap();
            let expect: Vec<u8> =
                a.iter().zip(&b).map(|(x, y)| x ^ y).collect();
            assert_eq!(out, expect);
        });
    }

    #[test]
    fn xor_is_involution_through_f32_views() {
        for_all("xor involution on tensors", |rng| {
            let n = rng.below(300) as usize;
            let a = f32_vec_adversarial(rng, n);
            let b = f32_vec_adversarial(rng, n);
            let mut patch = Vec::new();
            xor_into(&mut patch, as_bytes(&a), as_bytes(&b)).unwrap();
            let mut restored = b.clone();
            xor_in_place(as_bytes_mut(&mut restored), &patch).unwrap();
            assert!(a
                .iter()
                .zip(&restored)
                .all(|(x, y)| x.to_bits() == y.to_bits()));
        });
    }

    #[test]
    fn xor_length_mismatch_fails_closed() {
        let mut d = vec![0u8; 4];
        assert!(xor_in_place(&mut d, &[0u8; 5]).is_err());
        let mut out = Vec::new();
        assert!(xor_into(&mut out, &[0u8; 3], &[0u8; 4]).is_err());
    }

    #[test]
    fn first_mismatch_equals_scalar() {
        for_all("first_mismatch word == byte", |rng| {
            let n = rng.below(120) as usize;
            let a = byte_vec(rng, n);
            let mut b = a.clone();
            // flip one random byte half the time
            if n > 0 && rng.below(2) == 0 {
                let i = rng.below(n as u64) as usize;
                b[i] ^= (rng.below(255) + 1) as u8;
            }
            assert_eq!(first_mismatch(&a, &b), scalar::first_mismatch(&a, &b));
        });
    }

    #[test]
    fn first_mismatch_length_and_word_boundaries() {
        assert_eq!(first_mismatch(&[1, 2], &[1, 2, 3]), Some(2));
        let a = vec![0u8; 24];
        for flip in [0usize, 7, 8, 15, 16, 23] {
            let mut b = a.clone();
            b[flip] = 0xFF;
            assert_eq!(first_mismatch(&a, &b), Some(flip), "flip at {flip}");
        }
        assert_eq!(first_mismatch(&a, &a), None);
    }
}
