//! Minimal JSON encoder/parser (serde_json is not in the offline vendor
//! set — see Cargo.toml).  Covers the full JSON grammar we use: objects,
//! arrays, strings with escapes, numbers, bools, null.
//!
//! Used for `artifacts/manifest.json`, equality proofs, audit reports,
//! the signed forget manifest, and the admin-server wire format.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Object keys are kept sorted (BTreeMap) so encoding is
/// deterministic — important because manifests are content-addressed.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — construction bug).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `get_path(&["config", "param_count"])`.
    pub fn get_path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact deterministic encoding.
    pub fn encode(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding (2-space indent).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<f32> for Json {
    fn from(n: f32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}
impl<T: Into<Json> + Clone> From<&[T]> for Json {
    fn from(v: &[T]) -> Json {
        Json::Arr(v.iter().cloned().map(Into::into).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

/// Parse a JSON document.  Errors carry byte offsets for debugging.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?} at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hexs = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hexs, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, got {other:?}",
                        self.pos
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, got {other:?}",
                        self.pos
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut j = Json::obj();
        j.set("a", 1u64)
            .set("b", "hi\n\"there\"")
            .set("c", Json::Arr(vec![Json::Bool(true), Json::Null]))
            .set("d", 1.5f64);
        let enc = j.encode();
        let back = parse(&enc).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"x": {"y": [1, 2, {"z": "w"}]}, "n": -3.25e2}"#)
            .unwrap();
        assert_eq!(
            j.get_path(&["x", "y"]).unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(j.get("n").unwrap().as_f64().unwrap(), -325.0);
    }

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{
            "artifacts": {"train_step": {"file": "train_step.hlo.txt",
                          "inputs": [{"dtype": "float32", "shape": [120064]}]}},
            "config": {"param_count": 120064}
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(
            j.get_path(&["config", "param_count"]).unwrap().as_u64(),
            Some(120064)
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn deterministic_encoding() {
        // object keys sort -> byte-stable encoding for content addressing
        let mut a = Json::obj();
        a.set("z", 1u64).set("a", 2u64);
        let mut b = Json::obj();
        b.set("a", 2u64).set("z", 1u64);
        assert_eq!(a.encode(), b.encode());
    }
}
