//! Seeded property-testing harness (proptest is not in the offline vendor
//! set).  Provides the two pieces we actually use from a PBT library:
//! random case generation from a reproducible seed, and shrinking-free
//! failure reporting that prints the case seed so a failure replays
//! exactly with `CASE_SEED=<n> cargo test`.

use crate::util::rng::SplitMix64;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `f` on `cases` seeded RNGs; panic with the failing case seed.
///
/// ```ignore
/// for_all("xor involution", |rng| {
///     let n = rng.below(1000) as usize;
///     ...
/// });
/// ```
pub fn for_all<F: FnMut(&mut SplitMix64)>(name: &str, mut f: F) {
    let base: u64 = std::env::var("CASE_SEED")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0x5EED_0000);
    let cases = if std::env::var("CASE_SEED").is_ok() {
        1
    } else {
        default_cases()
    };
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed on case seed {seed} \
                 (replay: CASE_SEED={seed}): {msg}"
            );
        }
    }
}

/// Random f32 vector with entries ~ N(0, scale).
pub fn f32_vec(rng: &mut SplitMix64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| rng.normal() as f32 * scale).collect()
}

/// Random byte vector.
pub fn byte_vec(rng: &mut SplitMix64, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(256) as u8).collect()
}

/// Random f32 vector including adversarial bit patterns (NaN, ±0, inf,
/// denormals) — for exactness properties that must hold on raw bits.
pub fn f32_vec_adversarial(rng: &mut SplitMix64, len: usize) -> Vec<f32> {
    (0..len)
        .map(|_| match rng.below(8) {
            0 => f32::NAN,
            1 => -0.0,
            2 => f32::INFINITY,
            3 => f32::NEG_INFINITY,
            4 => f32::from_bits(rng.below(1 << 23) as u32), // denormal
            _ => rng.normal() as f32,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_runs_and_is_deterministic() {
        let mut sum1 = 0u64;
        for_all("accumulate", |rng| {
            sum1 = sum1.wrapping_add(rng.next_u64());
        });
        let mut sum2 = 0u64;
        for_all("accumulate", |rng| {
            sum2 = sum2.wrapping_add(rng.next_u64());
        });
        assert_eq!(sum1, sum2);
    }

    #[test]
    #[should_panic(expected = "case seed")]
    fn failure_reports_seed() {
        for_all("always fails", |_| panic!("boom"));
    }

    #[test]
    fn adversarial_includes_special_values() {
        let mut rng = SplitMix64::new(1);
        let v = f32_vec_adversarial(&mut rng, 4000);
        assert!(v.iter().any(|x| x.is_nan()));
        assert!(v.iter().any(|x| x.is_infinite()));
        assert!(v.iter().any(|x| x.to_bits() == 0x8000_0000)); // -0.0
    }
}
