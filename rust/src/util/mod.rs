//! Substrate utilities built in-repo (the image is offline; see Cargo.toml
//! for the vendored-crate constraint that motivates the DIY pieces).

pub mod bytes;
pub mod cli;
pub mod compress;
pub mod faultfs;
pub mod hashing;
pub mod json;
pub mod json_scan;
pub mod prop;
pub mod rng;
pub mod simd;

/// Fresh temp directory for tests and benches (unique per call).
pub fn tempdir(tag: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static N: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "unlearn-{tag}-{}-{}-{}",
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed),
        // detlint: allow(wall-clock) — uniqueness salt for a temp-dir
        // name; the value never reaches serialized or replayed state
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}
