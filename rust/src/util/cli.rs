//! Tiny CLI argument parser (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, which is everything the `unlearn` binary needs.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn get_f32(&self, name: &str, default: f32) -> anyhow::Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        // NOTE on grammar: `--name value` is always an option; bare flags
        // must therefore come last or be followed by another `--` token
        // (documented in the binary's --help).
        let a = args("train data.bin --steps 200 --lr=1e-3 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("steps"), Some("200"));
        assert_eq!(a.get_f32("lr", 0.0).unwrap(), 1e-3);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["data.bin"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = args("x --n notanumber");
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("n", 0).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = args("run --fast");
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
