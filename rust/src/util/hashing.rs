//! Content hashing for WAL records, artifacts and manifests.
//!
//! - [`sha256`] / [`sha256_file`] — segment and artifact integrity pins.
//! - [`hmac_sha256`] / [`hash64_keyed`] — the paper's production rule:
//!   `hash64` MUST be a keyed HMAC over the ordered sample IDs
//!   (HMAC-SHA256 truncated to 64 bits, Def. 1 security note).
//! - [`xxh64`] — fast non-cryptographic 64-bit hash (own implementation of
//!   the XXH64 algorithm) used for the toy-mode `hash64` and for content
//!   addressing hot paths.
//! - [`crc32`] — per-record WAL CRC.

use hmac::{Hmac, Mac};
use sha2::{Digest, Sha256};

/// SHA-256 of a byte slice, hex-encoded.
pub fn sha256_hex(data: &[u8]) -> String {
    hex(&sha256(data))
}

/// SHA-256 of a byte slice.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize().into()
}

/// Streaming SHA-256 of a file.
pub fn sha256_file(path: &std::path::Path) -> std::io::Result<String> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)?;
    let mut h = Sha256::new();
    let mut buf = vec![0u8; 1 << 20];
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        h.update(&buf[..n]);
    }
    Ok(hex(&h.finalize()))
}

/// Incremental SHA-256 hasher (for WAL segment checksums).
pub struct StreamingSha256(Sha256);

impl StreamingSha256 {
    pub fn new() -> Self {
        Self(Sha256::new())
    }
    pub fn update(&mut self, data: &[u8]) {
        self.0.update(data);
    }
    pub fn finalize_hex(self) -> String {
        hex(&self.0.finalize())
    }
}

impl Default for StreamingSha256 {
    fn default() -> Self {
        Self::new()
    }
}

/// HMAC-SHA256.
pub fn hmac_sha256(key: &[u8], data: &[u8]) -> [u8; 32] {
    let mut mac =
        Hmac::<Sha256>::new_from_slice(key).expect("hmac accepts any key len");
    mac.update(data);
    mac.finalize().into_bytes().into()
}

/// Keyed 64-bit content hash: HMAC-SHA256 truncated to 64 bits (big-endian
/// prefix), the paper's production `hash64` (Def. 1).
pub fn hash64_keyed(key: &[u8], data: &[u8]) -> u64 {
    let full = hmac_sha256(key, data);
    u64::from_be_bytes(full[..8].try_into().unwrap())
}

/// CRC32 (IEEE 802.3, reflected, poly 0xEDB88320) of a byte slice —
/// per-WAL-record checksum.  Own table-driven implementation (like
/// [`xxh64`] below, the crate set is pinned to anyhow/flate2/hmac/sha2);
/// the standard check value is locked in the tests.
pub fn crc32(data: &[u8]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut t = [0u32; 256];
        let mut i = 0usize;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
                k += 1;
            }
            t[i] = c;
            i += 1;
        }
        t
    };
    let mut c = !0u32;
    for &b in data {
        c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Hex-encode bytes (lowercase).
pub fn hex(data: &[u8]) -> String {
    let mut s = String::with_capacity(data.len() * 2);
    for b in data {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Decode lowercase/uppercase hex.
pub fn unhex(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(&s[2 * i..2 * i + 2], 16).ok())
        .collect()
}

// ---------------------------------------------------------------------------
// XXH64 (own implementation; reference test vectors below)
// ---------------------------------------------------------------------------

const P1: u64 = 0x9E3779B185EBCA87;
const P2: u64 = 0xC2B2AE3D27D4EB4F;
const P3: u64 = 0x165667B19E3779F9;
const P4: u64 = 0x85EBCA77C2B2AE63;
const P5: u64 = 0x27D4EB2F165667C5;

#[inline]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(P2))
        .rotate_left(31)
        .wrapping_mul(P1)
}

#[inline]
fn merge_round(acc: u64, val: u64) -> u64 {
    (acc ^ round(0, val)).wrapping_mul(P1).wrapping_add(P4)
}

#[inline]
fn read_u64(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

#[inline]
fn read_u32(b: &[u8]) -> u64 {
    u32::from_le_bytes(b[..4].try_into().unwrap()) as u64
}

/// XXH64 hash of `data` with `seed` — used as the toy-mode `hash64` over
/// ordered sample-ID byte strings (production mode uses [`hash64_keyed`]).
pub fn xxh64(data: &[u8], seed: u64) -> u64 {
    let len = data.len() as u64;
    let mut input = data;
    let mut h: u64;
    if input.len() >= 32 {
        let mut v1 = seed.wrapping_add(P1).wrapping_add(P2);
        let mut v2 = seed.wrapping_add(P2);
        let mut v3 = seed;
        let mut v4 = seed.wrapping_sub(P1);
        while input.len() >= 32 {
            v1 = round(v1, read_u64(&input[0..]));
            v2 = round(v2, read_u64(&input[8..]));
            v3 = round(v3, read_u64(&input[16..]));
            v4 = round(v4, read_u64(&input[24..]));
            input = &input[32..];
        }
        h = v1
            .rotate_left(1)
            .wrapping_add(v2.rotate_left(7))
            .wrapping_add(v3.rotate_left(12))
            .wrapping_add(v4.rotate_left(18));
        h = merge_round(h, v1);
        h = merge_round(h, v2);
        h = merge_round(h, v3);
        h = merge_round(h, v4);
    } else {
        h = seed.wrapping_add(P5);
    }
    h = h.wrapping_add(len);
    while input.len() >= 8 {
        h = (h ^ round(0, read_u64(input)))
            .rotate_left(27)
            .wrapping_mul(P1)
            .wrapping_add(P4);
        input = &input[8..];
    }
    if input.len() >= 4 {
        h = (h ^ read_u32(input).wrapping_mul(P1))
            .rotate_left(23)
            .wrapping_mul(P2)
            .wrapping_add(P3);
        input = &input[4..];
    }
    for &b in input {
        h = (h ^ (b as u64).wrapping_mul(P5))
            .rotate_left(11)
            .wrapping_mul(P1);
    }
    h ^= h >> 33;
    h = h.wrapping_mul(P2);
    h ^= h >> 29;
    h = h.wrapping_mul(P3);
    h ^= h >> 32;
    h
}

/// `hash64` over an *ordered* list of sample IDs (Def. 1): the order is
/// part of the hashed content — permuting IDs changes the hash.
pub fn hash_ordered_ids(ids: &[u64], key: Option<&[u8]>) -> u64 {
    let mut buf = Vec::with_capacity(ids.len() * 8);
    for id in ids {
        buf.extend_from_slice(&id.to_le_bytes());
    }
    match key {
        Some(k) => hash64_keyed(k, &buf),
        None => xxh64(&buf, 0x7a65706861726121), // "zephara!" toy seed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sha256_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn hmac_vector() {
        // RFC 4231 test case 2
        let out = hmac_sha256(b"Jefe", b"what do ya want for nothing?");
        assert_eq!(
            hex(&out),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn xxh64_vectors() {
        // reference vectors from the xxHash spec
        assert_eq!(xxh64(b"", 0), 0xEF46DB3751D8E999);
        assert_eq!(xxh64(b"a", 0), 0xD24EC4F1A98C6E5B);
        assert_eq!(xxh64(b"abc", 0), 0x44BC2CF5AD770999);
        // >=32B input exercises the 4-lane path (self-consistency + seed
        // sensitivity; short-input vectors above pin the algorithm)
        let long = b"0123456789abcdef0123456789abcdef0123456789";
        assert_eq!(xxh64(long, 7), xxh64(long, 7));
        assert_ne!(xxh64(long, 7), xxh64(long, 8));
        assert_ne!(xxh64(&long[..32], 0), xxh64(&long[..33], 0));
    }

    #[test]
    fn ordered_ids_order_sensitive() {
        let a = hash_ordered_ids(&[1, 2, 3], None);
        let b = hash_ordered_ids(&[3, 2, 1], None);
        let c = hash_ordered_ids(&[1, 2, 3], None);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn keyed_hash_differs_by_key() {
        let a = hash_ordered_ids(&[1, 2, 3], Some(b"key-a"));
        let b = hash_ordered_ids(&[1, 2, 3], Some(b"key-b"));
        assert_ne!(a, b);
    }

    #[test]
    fn crc32_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF43926);
    }

    #[test]
    fn hex_roundtrip() {
        let data = vec![0u8, 1, 127, 128, 255];
        assert_eq!(unhex(&hex(&data)).unwrap(), data);
        assert!(unhex("abc").is_none());
    }
}
