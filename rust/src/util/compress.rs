//! Lossless compression for dense per-step deltas (Table 8).
//!
//! The ring buffer stores per-step parameter deltas in the training dtype.
//! The paper reports "lossless compression (10-40% reduction typical)".
//! Raw f32 arithmetic deltas compress poorly as-is (mantissa entropy), so
//! we apply a *byte-plane transpose* first: the i-th bytes of every f32
//! are grouped together, which makes the exponent/sign planes highly
//! repetitive, then DEFLATE (flate2) the planes.  The transform is exactly
//! invertible — compression never touches bit patterns (G3 requirement).

use std::io::{Read, Write};

use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;

/// Byte-plane transpose: [a0 a1 a2 a3 b0 b1 ...] -> [a0 b0 .. a1 b1 ..].
/// Word size 4 (f32).  Length must be 4-aligned.
pub fn plane_split(data: &[u8]) -> Vec<u8> {
    assert_eq!(data.len() % 4, 0);
    let n = data.len() / 4;
    let mut out = vec![0u8; data.len()];
    for i in 0..n {
        for p in 0..4 {
            out[p * n + i] = data[i * 4 + p];
        }
    }
    out
}

/// Inverse of [`plane_split`].
pub fn plane_join(data: &[u8]) -> Vec<u8> {
    assert_eq!(data.len() % 4, 0);
    let n = data.len() / 4;
    let mut out = vec![0u8; data.len()];
    for i in 0..n {
        for p in 0..4 {
            out[i * 4 + p] = data[p * n + i];
        }
    }
    out
}

/// Compress a raw delta byte image (plane transform + DEFLATE).
pub fn compress_delta(data: &[u8]) -> Vec<u8> {
    let planes = plane_split(data);
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::fast());
    enc.write_all(&planes).expect("in-memory write");
    enc.finish().expect("in-memory finish")
}

/// Decompress a delta produced by [`compress_delta`].
pub fn decompress_delta(data: &[u8], expected_len: usize) -> anyhow::Result<Vec<u8>> {
    let mut dec = ZlibDecoder::new(data);
    let mut planes = Vec::with_capacity(expected_len);
    dec.read_to_end(&mut planes)?;
    anyhow::ensure!(
        planes.len() == expected_len,
        "decompressed length {} != expected {}",
        planes.len(),
        expected_len
    );
    Ok(plane_join(&planes))
}

/// Plain DEFLATE (no plane transform) — for WAL segments and manifests.
pub fn compress_raw(data: &[u8]) -> Vec<u8> {
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::default());
    enc.write_all(data).expect("in-memory write");
    enc.finish().expect("in-memory finish")
}

/// Inverse of [`compress_raw`].
pub fn decompress_raw(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut dec = ZlibDecoder::new(data);
    let mut out = Vec::new();
    dec.read_to_end(&mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitMix64;

    #[test]
    fn plane_roundtrip() {
        let data: Vec<u8> = (0..64u8).collect();
        assert_eq!(plane_join(&plane_split(&data)), data);
    }

    #[test]
    fn delta_roundtrip_exact() {
        let mut r = SplitMix64::new(5);
        // realistic delta: small values, shared exponent structure
        let vals: Vec<f32> = (0..10000)
            .map(|_| (r.normal() as f32) * 1e-4)
            .collect();
        let raw = crate::util::bytes::f32s_to_bytes(&vals);
        let comp = compress_delta(&raw);
        let back = decompress_delta(&comp, raw.len()).unwrap();
        assert_eq!(back, raw, "compression must be bit-lossless");
    }

    #[test]
    fn delta_compression_beats_identity_on_typical_updates() {
        let mut r = SplitMix64::new(9);
        let vals: Vec<f32> = (0..50000)
            .map(|_| (r.normal() as f32) * 3e-4)
            .collect();
        let raw = crate::util::bytes::f32s_to_bytes(&vals);
        let comp = compress_delta(&raw);
        let ratio = comp.len() as f64 / raw.len() as f64;
        assert!(ratio < 0.95, "expected some compression, got {ratio:.3}");
    }

    #[test]
    fn raw_roundtrip() {
        let data = b"the WAL is analogous to ARIES-style redo logging".repeat(10);
        let c = compress_raw(&data);
        assert!(c.len() < data.len());
        assert_eq!(decompress_raw(&c).unwrap(), data);
    }

    #[test]
    fn decompress_length_check() {
        let raw = vec![0u8; 64];
        let comp = compress_delta(&raw);
        assert!(decompress_delta(&comp, 60).is_err());
    }
}
