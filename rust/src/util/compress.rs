//! Lossless compression for dense per-step deltas (Table 8).
//!
//! The ring buffer stores per-step parameter deltas in the training dtype.
//! The paper reports "lossless compression (10-40% reduction typical)".
//! Raw f32 arithmetic deltas compress poorly as-is (mantissa entropy), so
//! we apply a *byte-plane transpose* first: the i-th bytes of every f32
//! are grouped together, which makes the exponent/sign planes highly
//! repetitive, then DEFLATE (flate2) the planes.  The transform is exactly
//! invertible — compression never touches bit patterns (G3 requirement).
//!
//! ## Hot-path architecture
//!
//! - The transpose is a single streaming pass: one sequential read
//!   cursor over the input and four sequential write cursors (one per
//!   byte plane), so every touched cache line is written densely instead
//!   of the seed's byte-scatter loop.
//! - [`plane_split_xor_into`] / [`plane_join_xor_in_place`] /
//!   [`plane_join_sub_f32_in_place`] fuse the XOR/arithmetic patch step
//!   into the transpose so `DeltaRing` never materializes a separate
//!   full-size XOR image (word-wise `u32` ops, zero-copy f32 views).
//! - DEFLATE runs per *plane shard*: the transposed buffer is split
//!   into deterministic, length-derived shards that compress and
//!   decompress independently on scoped threads
//!   (`std::thread::scope`), framed by [`FRAME_MAGIC`].
//!
//! ## Fail-closed posture (matches the WAL integrity rules)
//!
//! Corrupt input from disk must produce an `Err`, never a panic and
//! never an attacker-sized allocation: every length in the frame header
//! is validated against the caller's `expected_len` *before* any output
//! buffer is allocated, and each shard's inflate is capped at its
//! declared length (a decompression bomb errors instead of growing).

use std::io::{Read, Write};

use flate2::read::ZlibDecoder;
use flate2::write::ZlibEncoder;
use flate2::Compression;

/// Frame magic for the sharded delta format ("Unlearn Delta Frame v1").
pub const FRAME_MAGIC: [u8; 4] = *b"UDF1";
/// Flags bit: payload is byte-plane transposed.
const FLAG_PLANES: u8 = 1;
/// Target raw bytes per compression shard (256 KiB).
const SHARD_RAW_BYTES: usize = 256 * 1024;
/// Upper bound on shards per frame (also the decode-side sanity cap).
const MAX_SHARDS: usize = 16;
/// Fixed frame header: magic(4) flags(1) pad(3) raw_len(8) shards(4) pad(4).
const HEADER_LEN: usize = 24;
/// Per-shard table entry: raw_shard_len(8) comp_len(8).
const SHARD_HEADER_LEN: usize = 16;

// ---------------------------------------------------------------------------
// Byte-plane transpose (word size 4 = f32)
// ---------------------------------------------------------------------------

fn split4_mut(out: &mut [u8]) -> (&mut [u8], &mut [u8], &mut [u8], &mut [u8]) {
    let n = out.len() / 4;
    let (p0, rest) = out.split_at_mut(n);
    let (p1, rest) = rest.split_at_mut(n);
    let (p2, p3) = rest.split_at_mut(n);
    (p0, p1, p2, p3)
}

fn split4(planes: &[u8]) -> (&[u8], &[u8], &[u8], &[u8]) {
    let n = planes.len() / 4;
    let (p0, rest) = planes.split_at(n);
    let (p1, rest) = rest.split_at(n);
    let (p2, p3) = rest.split_at(n);
    (p0, p1, p2, p3)
}

/// Byte-plane transpose: [a0 a1 a2 a3 b0 b1 ...] -> [a0 b0 .. a1 b1 ..]
/// into a caller-provided buffer.  Word size 4 (f32).  Fails closed on
/// unaligned or mismatched lengths (corrupt input from disk).
pub fn plane_split_into(data: &[u8], out: &mut [u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        data.len() % 4 == 0,
        "plane transpose: length {} not 4-aligned",
        data.len()
    );
    anyhow::ensure!(
        out.len() == data.len(),
        "plane transpose: output {} != input {}",
        out.len(),
        data.len()
    );
    let (p0, p1, p2, p3) = split4_mut(out);
    for (i, w) in data.chunks_exact(4).enumerate() {
        p0[i] = w[0];
        p1[i] = w[1];
        p2[i] = w[2];
        p3[i] = w[3];
    }
    Ok(())
}

/// Allocating wrapper over [`plane_split_into`].
pub fn plane_split(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut out = vec![0u8; data.len()];
    plane_split_into(data, &mut out)?;
    Ok(out)
}

/// Inverse of [`plane_split`], into a caller-provided buffer.
pub fn plane_join_into(planes: &[u8], out: &mut [u8]) -> anyhow::Result<()> {
    anyhow::ensure!(
        planes.len() % 4 == 0,
        "plane join: length {} not 4-aligned",
        planes.len()
    );
    anyhow::ensure!(
        out.len() == planes.len(),
        "plane join: output {} != input {}",
        out.len(),
        planes.len()
    );
    let (p0, p1, p2, p3) = split4(planes);
    for (i, w) in out.chunks_exact_mut(4).enumerate() {
        w[0] = p0[i];
        w[1] = p1[i];
        w[2] = p2[i];
        w[3] = p3[i];
    }
    Ok(())
}

/// Allocating wrapper over [`plane_join_into`].
pub fn plane_join(planes: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut out = vec![0u8; planes.len()];
    plane_join_into(planes, &mut out)?;
    Ok(out)
}

/// Fused XOR + transpose: `out = plane_split(a ^ b)` in one pass, u32
/// word-wise, with no intermediate XOR image.  The `DeltaRing` record
/// hot path.
pub fn plane_split_xor_into(
    a: &[u8],
    b: &[u8],
    out: &mut [u8],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        a.len() == b.len(),
        "xor transpose: {} vs {}",
        a.len(),
        b.len()
    );
    anyhow::ensure!(a.len() % 4 == 0, "xor transpose: not 4-aligned");
    anyhow::ensure!(out.len() == a.len(), "xor transpose: bad output length");
    let (p0, p1, p2, p3) = split4_mut(out);
    for (i, (wa, wb)) in
        a.chunks_exact(4).zip(b.chunks_exact(4)).enumerate()
    {
        let x = u32::from_le_bytes(wa.try_into().unwrap())
            ^ u32::from_le_bytes(wb.try_into().unwrap());
        p0[i] = x as u8;
        p1[i] = (x >> 8) as u8;
        p2[i] = (x >> 16) as u8;
        p3[i] = (x >> 24) as u8;
    }
    Ok(())
}

/// Fused un-transpose + XOR apply: `dst ^= plane_join(planes)` in one
/// pass over the destination's zero-copy byte view.  The `DeltaRing`
/// XOR revert hot path.
pub fn plane_join_xor_in_place(
    planes: &[u8],
    dst: &mut [u8],
) -> anyhow::Result<()> {
    anyhow::ensure!(planes.len() % 4 == 0, "xor join: not 4-aligned");
    anyhow::ensure!(
        dst.len() == planes.len(),
        "xor join: dst {} != planes {}",
        dst.len(),
        planes.len()
    );
    let (p0, p1, p2, p3) = split4(planes);
    for (i, w) in dst.chunks_exact_mut(4).enumerate() {
        let patch = p0[i] as u32
            | (p1[i] as u32) << 8
            | (p2[i] as u32) << 16
            | (p3[i] as u32) << 24;
        let x = u32::from_le_bytes((&*w).try_into().unwrap()) ^ patch;
        w.copy_from_slice(&x.to_le_bytes());
    }
    Ok(())
}

/// Fused un-transpose + arithmetic revert: `dst[i] = fl(dst[i] - Δ_i)`
/// where the deltas are stored plane-transposed.  One pass, no joined
/// intermediate image.
pub fn plane_join_sub_f32_in_place(
    planes: &[u8],
    dst: &mut [f32],
) -> anyhow::Result<()> {
    anyhow::ensure!(
        planes.len() == dst.len() * 4,
        "arithmetic join: planes {} != 4*{}",
        planes.len(),
        dst.len()
    );
    let (p0, p1, p2, p3) = split4(planes);
    for (i, d) in dst.iter_mut().enumerate() {
        let bits = p0[i] as u32
            | (p1[i] as u32) << 8
            | (p2[i] as u32) << 16
            | (p3[i] as u32) << 24;
        *d -= f32::from_bits(bits); // fl(θ − Δ_t)
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Sharded framed DEFLATE
// ---------------------------------------------------------------------------

/// Deterministic, length-derived shard sizes (sum == `len`, count in
/// [1, MAX_SHARDS]).  Purely a function of `len` so the stored bytes do
/// not depend on the host's core count.
fn shard_sizes(len: usize) -> Vec<usize> {
    let count = if len == 0 {
        1
    } else {
        ((len + SHARD_RAW_BYTES - 1) / SHARD_RAW_BYTES).clamp(1, MAX_SHARDS)
    };
    let base = len / count;
    let rem = len % count;
    (0..count).map(|i| base + usize::from(i < rem)).collect()
}

fn deflate_shard(data: &[u8]) -> Vec<u8> {
    let mut enc = ZlibEncoder::new(
        Vec::with_capacity(data.len() / 2 + 64),
        Compression::fast(),
    );
    enc.write_all(data).expect("in-memory write");
    enc.finish().expect("in-memory finish")
}

/// Inflate exactly `out.len()` bytes from `comp` into `out`, refusing
/// both short streams and streams that continue past the declared
/// length (decompression-bomb cap).
fn inflate_shard_into(comp: &[u8], out: &mut [u8]) -> anyhow::Result<()> {
    let mut dec = ZlibDecoder::new(comp);
    dec.read_exact(out)
        .map_err(|e| anyhow::anyhow!("shard decompress: {e}"))?;
    let mut probe = [0u8; 1];
    let extra = dec
        .read(&mut probe)
        .map_err(|e| anyhow::anyhow!("shard trailer: {e}"))?;
    anyhow::ensure!(
        extra == 0,
        "shard inflates past its declared length (corrupt or hostile frame)"
    );
    Ok(())
}

/// Compress a payload into the sharded frame.  Shards ≥ 2 compress
/// concurrently on scoped threads.
fn compress_framed(data: &[u8], flags: u8) -> Vec<u8> {
    let sizes = shard_sizes(data.len());
    let mut shards: Vec<&[u8]> = Vec::with_capacity(sizes.len());
    let mut off = 0usize;
    for &s in &sizes {
        shards.push(&data[off..off + s]);
        off += s;
    }
    let comp: Vec<Vec<u8>> = if shards.len() == 1 {
        vec![deflate_shard(shards[0])]
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .iter()
                .map(|sh| scope.spawn(move || deflate_shard(sh)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("compress worker panicked"))
                .collect()
        })
    };
    let body: usize = comp
        .iter()
        .map(|c| SHARD_HEADER_LEN + c.len())
        .sum();
    let mut out = Vec::with_capacity(HEADER_LEN + body);
    out.extend_from_slice(&FRAME_MAGIC);
    out.push(flags);
    out.extend_from_slice(&[0u8; 3]);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    out.extend_from_slice(&(comp.len() as u32).to_le_bytes());
    out.extend_from_slice(&[0u8; 4]);
    for (raw, c) in sizes.iter().zip(&comp) {
        out.extend_from_slice(&(*raw as u64).to_le_bytes());
        out.extend_from_slice(&(c.len() as u64).to_le_bytes());
        out.extend_from_slice(c);
    }
    out
}

struct ShardRef {
    raw_len: usize,
    comp_start: usize,
    comp_end: usize,
}

fn read_u64(b: &[u8], off: usize) -> anyhow::Result<u64> {
    let s = b
        .get(off..off + 8)
        .ok_or_else(|| anyhow::anyhow!("frame truncated at offset {off}"))?;
    Ok(u64::from_le_bytes(s.try_into().unwrap()))
}

/// Parse + validate a frame against `expected_len`, then inflate.  All
/// header fields are checked before the output buffer is allocated, so
/// attacker-controlled metadata cannot drive allocation size.
fn decompress_framed(
    data: &[u8],
    expected_len: usize,
    expected_flags: u8,
) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(data.len() >= HEADER_LEN, "frame shorter than header");
    anyhow::ensure!(data[0..4] == FRAME_MAGIC, "bad frame magic");
    let flags = data[4];
    anyhow::ensure!(
        flags == expected_flags,
        "frame flags {flags:#x} != expected {expected_flags:#x}"
    );
    let raw_len = read_u64(data, 8)? as usize;
    anyhow::ensure!(
        raw_len == expected_len,
        "frame raw length {raw_len} != expected {expected_len}"
    );
    let shard_count = u32::from_le_bytes(data[16..20].try_into().unwrap()) as usize;
    anyhow::ensure!(
        (1..=MAX_SHARDS).contains(&shard_count),
        "implausible shard count {shard_count}"
    );

    let mut shards = Vec::with_capacity(shard_count);
    let mut off = HEADER_LEN;
    let mut raw_sum = 0usize;
    for _ in 0..shard_count {
        let raw = read_u64(data, off)? as usize;
        let comp = read_u64(data, off + 8)? as usize;
        off += SHARD_HEADER_LEN;
        anyhow::ensure!(
            raw <= expected_len && raw_sum + raw <= expected_len,
            "shard raw lengths exceed expected {expected_len}"
        );
        anyhow::ensure!(
            comp <= data.len() && off + comp <= data.len(),
            "shard compressed range out of bounds"
        );
        shards.push(ShardRef {
            raw_len: raw,
            comp_start: off,
            comp_end: off + comp,
        });
        raw_sum += raw;
        off += comp;
    }
    anyhow::ensure!(
        raw_sum == expected_len,
        "shard raw lengths sum to {raw_sum}, expected {expected_len}"
    );
    anyhow::ensure!(off == data.len(), "trailing garbage after last shard");

    // lengths validated — the allocation below is exactly expected_len
    let mut out = vec![0u8; expected_len];
    if shards.len() == 1 {
        let sh = &shards[0];
        inflate_shard_into(&data[sh.comp_start..sh.comp_end], &mut out)?;
    } else {
        let results: Vec<anyhow::Result<()>> = std::thread::scope(|scope| {
            let mut rest: &mut [u8] = &mut out;
            let mut handles = Vec::with_capacity(shards.len());
            for sh in &shards {
                let (head, tail) =
                    std::mem::take(&mut rest).split_at_mut(sh.raw_len);
                rest = tail;
                let comp = &data[sh.comp_start..sh.comp_end];
                handles.push(scope.spawn(move || inflate_shard_into(comp, head)));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("decompress worker panicked"))
                .collect()
        });
        for r in results {
            r?;
        }
    }
    Ok(out)
}

/// Compress a raw delta byte image (plane transform + sharded DEFLATE).
pub fn compress_delta(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let planes = plane_split(data)?;
    Ok(compress_framed(&planes, FLAG_PLANES))
}

/// Compress an already plane-transposed buffer (the `DeltaRing` path:
/// the fused XOR+transpose writes planes directly, so no extra pass).
pub fn compress_planes(planes: &[u8]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(planes.len() % 4 == 0, "planes not 4-aligned");
    Ok(compress_framed(planes, FLAG_PLANES))
}

/// Decompress a delta produced by [`compress_delta`]/[`compress_planes`]
/// back to the *plane-transposed* buffer (callers fuse the join into
/// their apply step).
pub fn decompress_planes(
    data: &[u8],
    expected_len: usize,
) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(expected_len % 4 == 0, "expected length not 4-aligned");
    decompress_framed(data, expected_len, FLAG_PLANES)
}

/// Decompress a delta produced by [`compress_delta`] to its raw byte
/// image (un-transposed).
pub fn decompress_delta(
    data: &[u8],
    expected_len: usize,
) -> anyhow::Result<Vec<u8>> {
    let planes = decompress_planes(data, expected_len)?;
    plane_join(&planes)
}

/// Plain DEFLATE (no plane transform) — for WAL segments and manifests.
pub fn compress_raw(data: &[u8]) -> Vec<u8> {
    let mut enc = ZlibEncoder::new(Vec::new(), Compression::default());
    enc.write_all(data).expect("in-memory write");
    enc.finish().expect("in-memory finish")
}

/// Inverse of [`compress_raw`].  Unbounded output — suitable for
/// in-memory/trusted streams only.  No production path currently
/// compresses with `compress_raw`; any future caller that reads the
/// stream from disk must use [`decompress_raw_capped`] instead.
pub fn decompress_raw(data: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut dec = ZlibDecoder::new(data);
    let mut out = Vec::new();
    dec.read_to_end(&mut out)?;
    Ok(out)
}

/// [`decompress_raw`] with an output cap: errors (fail-closed) instead
/// of allocating past `max_len` on a hostile stream.  The delta/ring
/// path does not use this (its framed format carries validated
/// lengths); it exists so future disk-facing raw-zlib callers start
/// capped.
pub fn decompress_raw_capped(
    data: &[u8],
    max_len: usize,
) -> anyhow::Result<Vec<u8>> {
    let mut dec = ZlibDecoder::new(data).take(max_len as u64 + 1);
    let mut out = Vec::new();
    dec.read_to_end(&mut out)?;
    anyhow::ensure!(
        out.len() <= max_len,
        "stream inflates past the {max_len}-byte cap"
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{f32_vec_adversarial, for_all};
    use crate::util::rng::SplitMix64;
    use crate::util::simd;

    #[test]
    fn plane_roundtrip() {
        let data: Vec<u8> = (0..64u8).collect();
        assert_eq!(plane_join(&plane_split(&data).unwrap()).unwrap(), data);
    }

    #[test]
    fn plane_rejects_unaligned_instead_of_panicking() {
        assert!(plane_split(&[1, 2, 3]).is_err());
        assert!(plane_join(&[1, 2, 3]).is_err());
        let mut out = vec![0u8; 3];
        assert!(plane_split_into(&[1, 2, 3, 4], &mut out).is_err());
    }

    #[test]
    fn fused_xor_split_matches_composition() {
        for_all("split(a^b) == split_xor(a,b)", |rng| {
            let n = rng.below(300) as usize;
            let a = f32_vec_adversarial(rng, n);
            let b = f32_vec_adversarial(rng, n);
            let ab = simd::as_bytes(&a);
            let bb = simd::as_bytes(&b);
            let mut xored = ab.to_vec();
            simd::xor_in_place(&mut xored, bb).unwrap();
            let expect = plane_split(&xored).unwrap();
            let mut fused = vec![0u8; ab.len()];
            plane_split_xor_into(ab, bb, &mut fused).unwrap();
            assert_eq!(fused, expect);
        });
    }

    #[test]
    fn fused_xor_join_reverts_bit_exact() {
        for_all("join_xor revert", |rng| {
            let n = rng.below(300) as usize;
            let before = f32_vec_adversarial(rng, n);
            let after = f32_vec_adversarial(rng, n);
            let mut planes = vec![0u8; n * 4];
            plane_split_xor_into(
                simd::as_bytes(&after),
                simd::as_bytes(&before),
                &mut planes,
            )
            .unwrap();
            let mut cur = after.clone();
            plane_join_xor_in_place(&planes, simd::as_bytes_mut(&mut cur))
                .unwrap();
            assert!(crate::util::bytes::bits_equal(&cur, &before));
        });
    }

    #[test]
    fn fused_sub_join_matches_scalar_subtract() {
        for_all("join_sub == join + subtract", |rng| {
            let n = rng.below(200) as usize;
            let delta = crate::util::prop::f32_vec(rng, n, 1e-3);
            let cur0 = crate::util::prop::f32_vec(rng, n, 1.0);
            let planes = plane_split(simd::as_bytes(&delta)).unwrap();
            let mut fused = cur0.clone();
            plane_join_sub_f32_in_place(&planes, &mut fused).unwrap();
            let expect: Vec<f32> =
                cur0.iter().zip(&delta).map(|(c, d)| c - d).collect();
            assert!(crate::util::bytes::bits_equal(&fused, &expect));
        });
    }

    #[test]
    fn delta_roundtrip_exact() {
        let mut r = SplitMix64::new(5);
        // realistic delta: small values, shared exponent structure
        let vals: Vec<f32> = (0..10000)
            .map(|_| (r.normal() as f32) * 1e-4)
            .collect();
        let raw = crate::util::bytes::f32s_to_bytes(&vals);
        let comp = compress_delta(&raw).unwrap();
        let back = decompress_delta(&comp, raw.len()).unwrap();
        assert_eq!(back, raw, "compression must be bit-lossless");
    }

    #[test]
    fn delta_roundtrip_adversarial_bits() {
        for_all("sharded framing lossless on nan/-0/denormals", |rng| {
            let n = rng.below(2000) as usize;
            let vals = f32_vec_adversarial(rng, n);
            let raw = simd::as_bytes(&vals);
            let comp = compress_delta(raw).unwrap();
            assert_eq!(decompress_delta(&comp, raw.len()).unwrap(), raw);
            // planes path used by the ring
            let planes = plane_split(raw).unwrap();
            let comp2 = compress_planes(&planes).unwrap();
            assert_eq!(decompress_planes(&comp2, raw.len()).unwrap(), planes);
        });
    }

    #[test]
    fn multi_shard_roundtrip() {
        // > SHARD_RAW_BYTES so the frame carries several shards
        let mut r = SplitMix64::new(11);
        let vals: Vec<f32> = (0..300_000)
            .map(|_| (r.normal() as f32) * 1e-4)
            .collect();
        let raw = simd::as_bytes(&vals);
        assert!(raw.len() > SHARD_RAW_BYTES * 2);
        let comp = compress_delta(raw).unwrap();
        let count = u32::from_le_bytes(comp[16..20].try_into().unwrap());
        assert!(count >= 2, "expected multiple shards, got {count}");
        assert_eq!(decompress_delta(&comp, raw.len()).unwrap(), raw);
    }

    #[test]
    fn shard_sizes_are_deterministic_and_cover() {
        for len in [0usize, 1, 4, SHARD_RAW_BYTES, SHARD_RAW_BYTES * 3 + 17,
                    SHARD_RAW_BYTES * 100] {
            let a = shard_sizes(len);
            assert_eq!(a, shard_sizes(len));
            assert_eq!(a.iter().sum::<usize>(), len);
            assert!(!a.is_empty() && a.len() <= MAX_SHARDS);
        }
    }

    #[test]
    fn delta_compression_beats_identity_on_typical_updates() {
        let mut r = SplitMix64::new(9);
        let vals: Vec<f32> = (0..50000)
            .map(|_| (r.normal() as f32) * 3e-4)
            .collect();
        let raw = crate::util::bytes::f32s_to_bytes(&vals);
        let comp = compress_delta(&raw).unwrap();
        let ratio = comp.len() as f64 / raw.len() as f64;
        assert!(ratio < 0.95, "expected some compression, got {ratio:.3}");
    }

    #[test]
    fn raw_roundtrip() {
        let data = b"the WAL is analogous to ARIES-style redo logging".repeat(10);
        let c = compress_raw(&data);
        assert!(c.len() < data.len());
        assert_eq!(decompress_raw(&c).unwrap(), data);
        assert_eq!(decompress_raw_capped(&c, data.len()).unwrap(), data);
        assert!(decompress_raw_capped(&c, data.len() - 1).is_err());
    }

    #[test]
    fn decompress_length_check() {
        let raw = vec![0u8; 64];
        let comp = compress_delta(&raw).unwrap();
        assert!(decompress_delta(&comp, 60).is_err());
        assert!(decompress_delta(&comp, 68).is_err());
    }

    #[test]
    fn corrupt_frames_fail_closed() {
        let raw = vec![7u8; 256];
        let good = compress_delta(&raw).unwrap();

        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(decompress_delta(&bad, raw.len()).is_err());

        // truncated header / body
        assert!(decompress_delta(&good[..10], raw.len()).is_err());
        assert!(
            decompress_delta(&good[..good.len() - 1], raw.len()).is_err()
        );

        // lying raw_len (attacker-controlled allocation metadata)
        let mut bad = good.clone();
        bad[8..16].copy_from_slice(&(usize::MAX as u64).to_le_bytes());
        assert!(decompress_delta(&bad, raw.len()).is_err());

        // implausible shard count
        let mut bad = good.clone();
        bad[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decompress_delta(&bad, raw.len()).is_err());

        // shard table declaring more raw bytes than the frame total
        let mut bad = good.clone();
        bad[HEADER_LEN..HEADER_LEN + 8]
            .copy_from_slice(&(1u64 << 40).to_le_bytes());
        assert!(decompress_delta(&bad, raw.len()).is_err());

        // flipped compressed payload byte
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x10;
        assert!(decompress_delta(&bad, raw.len()).is_err());
    }

    #[test]
    fn empty_delta_roundtrips() {
        let comp = compress_delta(&[]).unwrap();
        assert_eq!(decompress_delta(&comp, 0).unwrap(), Vec::<u8>::new());
    }
}
