//! Deterministic fault injection for erasure-critical filesystem I/O.
//!
//! GDPR-grade deletion is a durability obligation: a crash mid-launder
//! or a torn WAL write must never resurrect forgotten data or drop an
//! acked erasure.  Proving that requires *driving* every persistence
//! sequence through every crash point — so the mutating operations of
//! the erasure-critical paths (`checkpoint::write_atomic`, CAS object
//! writes, lineage stage/commit/retire, the IdMap retired sidecar, the
//! jobs-WAL append+fsync) are routed through this shim.
//!
//! Unarmed (the production state) every wrapper is a straight
//! passthrough to `std::fs` guarded by one relaxed atomic load.  A test
//! arms an [`Injector`] against a directory *root* (its own tempdir);
//! only operations whose paths fall under that root are intercepted,
//! so parallel tests cannot contaminate each other and worker threads
//! inside scoped thread pools are covered without thread-local plumbing.
//!
//! Fault model (all deterministic — philox-seeded, no wall clock):
//! - [`Plan::Count`]: observe, never interfere.  The crash matrix runs
//!   each sequence once in count mode to learn its op count `n`, then
//!   sweeps crash points `0..n`.
//! - [`Plan::FailAt`]: the k-th matching op returns an I/O error and
//!   the filesystem stays online — a transient error surfaced to the
//!   caller's error path.
//! - [`Plan::CrashAt`]: the k-th matching op fails and every later op
//!   under the root fails too ("process died here"); with `torn`, a
//!   crashing write first persists a philox-seeded byte prefix — the
//!   torn-write model for appends and tmp-file writes.  Recovery is
//!   modeled by dropping the in-memory state, disarming, and reopening
//!   through the normal open/recovery paths.

use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::rng::philox_u64;

/// What an armed injector does to intercepted operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Plan {
    /// Count matching mutating ops without interfering.
    Count,
    /// Fail the `op`-th matching operation (0-based) with an I/O error;
    /// later operations proceed normally.
    FailAt { op: u64 },
    /// Crash at the `op`-th matching operation: it fails, and every
    /// subsequent operation under the same root fails until the
    /// injector is disarmed.  `torn` persists a philox-seeded byte
    /// prefix of the crashing write before failing.
    CrashAt { op: u64, torn: bool, seed: u64 },
}

#[derive(Debug)]
struct Inner {
    root: PathBuf,
    plan: Plan,
    ops: AtomicU64,
    crashed: AtomicBool,
}

/// How a crashing write would have mutated the file — determines what
/// a torn prefix does to the bytes already on disk.
#[derive(Debug, Clone, Copy)]
enum WriteKind {
    /// Create/truncate-then-write (tmp files, checksums).
    Truncate,
    /// Append to the existing file (WAL lines).
    Append,
}

impl Inner {
    /// Count this op and decide its fate.  `tear` carries the write
    /// payload when the op is tearable.
    fn gate(&self, tear: Option<(&Path, &[u8], WriteKind)>) -> io::Result<()> {
        if self.crashed.load(Ordering::SeqCst) {
            return Err(io::Error::other(
                "faultfs: filesystem offline after simulated crash",
            ));
        }
        let idx = self.ops.fetch_add(1, Ordering::SeqCst);
        match self.plan {
            Plan::Count => Ok(()),
            Plan::FailAt { op } if idx == op => Err(io::Error::other(
                format!("faultfs: injected I/O error at op {idx}"),
            )),
            Plan::FailAt { .. } => Ok(()),
            Plan::CrashAt { op, torn, seed } if idx >= op => {
                if idx == op && torn {
                    if let Some((path, bytes, kind)) = tear {
                        // Persist a deterministic prefix: the bytes that
                        // "made it to disk" before the crash.  Best
                        // effort — the crash error is what matters.
                        let keep = (philox_u64(seed, idx) as usize)
                            % (bytes.len() + 1);
                        let _ = match kind {
                            WriteKind::Truncate => {
                                std::fs::write(path, &bytes[..keep])
                            }
                            WriteKind::Append => {
                                append_raw(path, &bytes[..keep])
                            }
                        };
                    }
                }
                self.crashed.store(true, Ordering::SeqCst);
                Err(io::Error::other(format!(
                    "faultfs: simulated crash at op {idx}"
                )))
            }
            Plan::CrashAt { .. } => Ok(()),
        }
    }
}

/// RAII guard for an armed injector — dropping it disarms.
pub struct Injector {
    inner: Arc<Inner>,
}

impl Injector {
    /// Matching mutating ops observed so far.
    pub fn ops(&self) -> u64 {
        self.inner.ops.load(Ordering::SeqCst)
    }

    /// True once a [`Plan::CrashAt`] point has fired.
    pub fn crashed(&self) -> bool {
        self.inner.crashed.load(Ordering::SeqCst)
    }
}

impl Drop for Injector {
    fn drop(&mut self) {
        let mut reg = registry().lock().unwrap_or_else(|p| p.into_inner());
        reg.retain(|i| !Arc::ptr_eq(i, &self.inner));
        ARMED.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Count of armed injectors — the one-load fast path for production
/// code, where every wrapper must cost a relaxed atomic read and
/// nothing else.
static ARMED: AtomicU64 = AtomicU64::new(0);

fn registry() -> &'static Mutex<Vec<Arc<Inner>>> {
    static R: OnceLock<Mutex<Vec<Arc<Inner>>>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Vec::new()))
}

/// Arm an injector over every path under `root`.
pub fn arm(root: &Path, plan: Plan) -> Injector {
    let inner = Arc::new(Inner {
        root: root.to_path_buf(),
        plan,
        ops: AtomicU64::new(0),
        crashed: AtomicBool::new(false),
    });
    registry()
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .push(inner.clone());
    ARMED.fetch_add(1, Ordering::SeqCst);
    Injector { inner }
}

/// The injector (if any) whose root covers one of `paths`.
fn injector_for(paths: &[&Path]) -> Option<Arc<Inner>> {
    if ARMED.load(Ordering::Relaxed) == 0 {
        return None;
    }
    let reg = registry().lock().unwrap_or_else(|p| p.into_inner());
    reg.iter()
        .find(|i| paths.iter().any(|p| p.starts_with(&i.root)))
        .cloned()
}

fn append_raw(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let mut f = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)?;
    f.write_all(bytes)
}

/// Create/truncate `path` and write `bytes` (chunked, so multi-MiB
/// tensor blobs stream through a bounded buffer).
pub fn write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    if let Some(inj) = injector_for(&[path]) {
        inj.gate(Some((path, bytes, WriteKind::Truncate)))?;
    }
    let f = std::fs::File::create(path)?;
    let mut w = io::BufWriter::with_capacity(1 << 20, f);
    for chunk in bytes.chunks(1 << 20) {
        w.write_all(chunk)?;
    }
    w.flush()
}

/// Append `bytes` to `path` (creating it if absent).
pub fn append(path: &Path, bytes: &[u8]) -> io::Result<()> {
    if let Some(inj) = injector_for(&[path]) {
        inj.gate(Some((path, bytes, WriteKind::Append)))?;
    }
    append_raw(path, bytes)
}

/// Flush `path`'s data and metadata to stable storage.  A distinct
/// crash point from the append that preceded it: the fsync-before-ack
/// proof needs "crashed after write, before sync" enumerable.
pub fn fsync(path: &Path) -> io::Result<()> {
    if let Some(inj) = injector_for(&[path]) {
        inj.gate(None)?;
    }
    std::fs::File::open(path)?.sync_all()
}

/// Atomic rename (the commit point of every tmp+rename sequence).
pub fn rename(from: &Path, to: &Path) -> io::Result<()> {
    if let Some(inj) = injector_for(&[from, to]) {
        inj.gate(None)?;
    }
    std::fs::rename(from, to)
}

/// File copy (lineage stage adoption of clean checkpoints).
pub fn copy(from: &Path, to: &Path) -> io::Result<u64> {
    if let Some(inj) = injector_for(&[from, to]) {
        inj.gate(None)?;
    }
    std::fs::copy(from, to)
}

/// Remove one file (CAS garbage collection, manifest pruning).
pub fn remove_file(path: &Path) -> io::Result<()> {
    if let Some(inj) = injector_for(&[path]) {
        inj.gate(None)?;
    }
    std::fs::remove_file(path)
}

/// Remove a directory tree (retiring a superseded lineage).
pub fn remove_dir_all(path: &Path) -> io::Result<()> {
    if let Some(inj) = injector_for(&[path]) {
        inj.gate(None)?;
    }
    std::fs::remove_dir_all(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;

    #[test]
    fn unarmed_is_passthrough() {
        let dir = tempdir("faultfs-pass");
        let p = dir.join("a.txt");
        write(&p, b"hello").unwrap();
        append(&p, b" world").unwrap();
        fsync(&p).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"hello world");
        rename(&p, &dir.join("b.txt")).unwrap();
        remove_file(&dir.join("b.txt")).unwrap();
    }

    #[test]
    fn count_mode_counts_without_interfering() {
        let dir = tempdir("faultfs-count");
        let inj = arm(&dir, Plan::Count);
        let p = dir.join("a.txt");
        write(&p, b"x").unwrap();
        append(&p, b"y").unwrap();
        fsync(&p).unwrap();
        rename(&p, &dir.join("b.txt")).unwrap();
        assert_eq!(inj.ops(), 4);
        assert!(!inj.crashed());
        assert_eq!(std::fs::read(dir.join("b.txt")).unwrap(), b"xy");
    }

    #[test]
    fn non_matching_root_is_untouched() {
        let dir = tempdir("faultfs-scope-a");
        let other = tempdir("faultfs-scope-b");
        let inj = arm(&dir, Plan::CrashAt { op: 0, torn: false, seed: 1 });
        // ops outside the armed root pass through and are not counted
        write(&other.join("a.txt"), b"ok").unwrap();
        assert_eq!(inj.ops(), 0);
        assert!(write(&dir.join("a.txt"), b"no").is_err());
    }

    #[test]
    fn fail_at_is_transient() {
        let dir = tempdir("faultfs-failat");
        let inj = arm(&dir, Plan::FailAt { op: 1 });
        let p = dir.join("a.txt");
        write(&p, b"one").unwrap(); // op 0: ok
        assert!(write(&p, b"two").is_err()); // op 1: injected error
        write(&p, b"three").unwrap(); // op 2: back online
        assert_eq!(std::fs::read(&p).unwrap(), b"three");
        assert!(!inj.crashed());
    }

    #[test]
    fn crash_takes_filesystem_offline() {
        let dir = tempdir("faultfs-crash");
        let inj = arm(&dir, Plan::CrashAt { op: 1, torn: false, seed: 7 });
        let p = dir.join("a.txt");
        write(&p, b"pre").unwrap();
        assert!(write(&p, b"crash").is_err());
        assert!(inj.crashed());
        assert!(append(&p, b"post").is_err());
        assert!(remove_file(&p).is_err());
        // the crash-point write (torn=false) left no partial effect
        assert_eq!(std::fs::read(&p).unwrap(), b"pre");
        drop(inj); // disarm = recovery boundary
        write(&p, b"recovered").unwrap();
    }

    #[test]
    fn torn_write_persists_philox_prefix() {
        let dir = tempdir("faultfs-torn");
        let p = dir.join("wal.log");
        append(&p, b"line-1\n").unwrap();
        let seed = 99u64;
        let inj = arm(&dir, Plan::CrashAt { op: 0, torn: true, seed });
        let payload = b"line-2-payload\n";
        assert!(append(&p, payload).is_err());
        drop(inj);
        let keep = (philox_u64(seed, 0) as usize) % (payload.len() + 1);
        let mut expect = b"line-1\n".to_vec();
        expect.extend_from_slice(&payload[..keep]);
        assert_eq!(std::fs::read(&p).unwrap(), expect, "prefix of len {keep}");
        // determinism: same seed, same tear
        assert_eq!(
            (philox_u64(seed, 0) as usize) % (payload.len() + 1),
            keep
        );
    }

    #[test]
    fn drop_disarms() {
        let dir = tempdir("faultfs-drop");
        {
            let _inj = arm(&dir, Plan::CrashAt { op: 0, torn: false, seed: 1 });
            assert!(write(&dir.join("a"), b"x").is_err());
        }
        write(&dir.join("a"), b"x").unwrap();
    }
}
