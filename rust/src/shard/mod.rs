//! Deterministic user→shard partitioning (the SISA-style fleet layer's
//! foundation): every user is pinned to exactly one of `n_shards`
//! shards by a keyed counter-based hash, so forgetting user `u` can
//! only ever touch `shard(u)` — the cost of exact unlearning scales
//! with `1/N` of the corpus instead of the whole run.
//!
//! The assignment is a *pure function* of `(user, salt, n_shards)`:
//! no table, no state, nothing to migrate — and therefore nothing that
//! can silently drift between training and replay.  The topology is
//! additionally **pinned**: [`ShardSpec::pin_for`] produces the string
//! each shard's trainer stamps into its [`crate::config::Pins`]
//! (`pins.shard`), so replaying a shard's WAL under a different
//! topology (changed `n_shards`, changed salt, or an unsharded reopen)
//! fails closed in `Pins::ensure_match` — in both directions.
//!
//! [`split_corpus`] partitions a corpus by *document ownership* at
//! ingest: each shard receives exactly the samples whose owning user
//! hashes to it, with dense shard-local sample IDs (the per-shard
//! trainer/WAL/IdMap never see global IDs) and a bidirectional
//! global↔local mapping the fleet router uses to scatter cross-shard
//! closures.

use std::collections::HashMap;
use std::path::Path;

use crate::data::corpus::{Corpus, Sample, SampleKind};
use crate::util::json::{parse, Json};
use crate::util::rng::philox_u64;

/// Keyed domain separator so shard assignment never collides with any
/// other `philox_u64` use of the same salt.
const SHARD_DOMAIN: u64 = 0x5A4D_5348_4152_4421;

/// The pinned fleet topology: how many shards, and the salt that keys
/// the user→shard hash.  Changing either re-routes users, so both are
/// part of every shard's reproducibility pins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub n_shards: u32,
    pub salt: u64,
}

impl ShardSpec {
    /// The owning shard of `user` — a pure function of
    /// `(user, salt, n_shards)`; no state, no I/O, no ordering effects.
    pub fn assign(&self, user: u32) -> u32 {
        debug_assert!(self.n_shards > 0);
        (philox_u64(self.salt ^ SHARD_DOMAIN, user as u64)
            % self.n_shards.max(1) as u64) as u32
    }

    /// The topology pin string shard `shard`'s trainer stamps into its
    /// `Pins.shard`: shard index, shard count and salt.  Any topology
    /// drift — different `n_shards`, different salt, a shard's run dir
    /// opened as a different shard index, or a sharded run reopened
    /// unsharded (empty pin) — makes this string differ and the pin
    /// check refuses the replay.
    pub fn pin_for(&self, shard: u32) -> String {
        format!(
            "shard {}/{} salt {:016x}",
            shard, self.n_shards, self.salt
        )
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        // hex, not a JSON number: the f64-backed number type would
        // silently round salts above 2^53 and the pinned topology must
        // roundtrip bit-exactly
        j.set("n_shards", self.n_shards)
            .set("salt_hex", format!("{:016x}", self.salt));
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ShardSpec> {
        let n_shards = j
            .get("n_shards")
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow::anyhow!("shard spec missing n_shards"))?
            as u32;
        anyhow::ensure!(n_shards > 0, "shard spec needs n_shards > 0");
        let salt_hex = j
            .get("salt_hex")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("shard spec missing salt_hex"))?;
        Ok(ShardSpec {
            n_shards,
            salt: u64::from_str_radix(salt_hex, 16)
                .map_err(|e| anyhow::anyhow!("bad salt_hex {salt_hex:?}: {e}"))?,
        })
    }

    /// Persist the topology at the fleet root (atomic tmp+rename).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        crate::checkpoint::write_atomic(path, &self.to_json().pretty())
    }

    pub fn load(path: &Path) -> anyhow::Result<ShardSpec> {
        let j = parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("fleet spec {}: {e}", path.display()))?;
        ShardSpec::from_json(&j)
    }
}

/// The ownership partition of one corpus: per-shard sub-corpora with
/// dense local IDs plus the global→local mapping the fleet router uses.
#[derive(Debug, Clone)]
pub struct ShardSplit {
    /// Shard-local corpora (index = shard).  A shard whose user set is
    /// empty gets an empty corpus; the fleet skips training it.  (The
    /// fleet moves these into the shard systems at build and leaves
    /// this vector empty.)
    pub corpora: Vec<Corpus>,
    /// global sample id → (owning shard, shard-local id).
    pub locate: HashMap<u64, (u32, u64)>,
}

impl ShardSplit {
    /// The owning shard of a global sample id.
    pub fn shard_of(&self, global_id: u64) -> Option<u32> {
        self.locate.get(&global_id).map(|&(s, _)| s)
    }

    /// Shard-local id of a global sample id.
    pub fn local_of(&self, global_id: u64) -> Option<(u32, u64)> {
        self.locate.get(&global_id).copied()
    }
}

/// Partition `corpus` by document ownership: sample `x` lands in
/// `spec.assign(x.user)`, in global-ID order, with dense local IDs.
/// Near-dup back-references are remapped to local IDs when the original
/// lives in the same shard; a cross-owner duplicate whose original was
/// routed elsewhere keeps its text/tokens but degrades to
/// `SampleKind::Normal` (the reference would dangle — nothing at
/// runtime consumes `of`, but a shard corpus must be self-contained).
pub fn split_corpus(spec: &ShardSpec, corpus: &Corpus) -> ShardSplit {
    let n = spec.n_shards as usize;
    let mut corpora: Vec<Corpus> = (0..n)
        .map(|_| Corpus {
            samples: Vec::new(),
            config: corpus.config.clone(),
        })
        .collect();
    let mut locate: HashMap<u64, (u32, u64)> = HashMap::new();

    for s in &corpus.samples {
        let shard = spec.assign(s.user);
        let local = corpora[shard as usize].samples.len() as u64;
        locate.insert(s.id, (shard, local));
        corpora[shard as usize].samples.push(Sample {
            id: local,
            user: s.user,
            cohort: s.cohort,
            kind: s.kind.clone(),
            text: s.text.clone(),
            tokens: s.tokens.clone(),
        });
    }
    // second pass: fix near-dup back-references to shard-local ids
    for (shard, c) in corpora.iter_mut().enumerate() {
        for s in &mut c.samples {
            if let SampleKind::NearDup { of } = s.kind {
                s.kind = match locate.get(&of) {
                    Some(&(os, ol)) if os as usize == shard => {
                        SampleKind::NearDup { of: ol }
                    }
                    _ => SampleKind::Normal,
                };
            }
        }
    }
    ShardSplit { corpora, locate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pins;
    use crate::data::corpus::CorpusConfig;
    use crate::util::prop::for_all;

    fn base_pins() -> Pins {
        Pins {
            executor_kind: "reference".into(),
            shard: String::new(),
            artifact_hashes: vec![("train_step".into(), "aaa".into())],
            model_config_hash: "cfg".into(),
            tokenizer_checksum: "tok".into(),
            param_count: 100,
            accum: 2,
            batch: 8,
            layout: "single-host;dp=1;tp=1;pp=1".into(),
            reduction: "sum".into(),
            platform: "cpu".into(),
        }
    }

    #[test]
    fn assignment_is_a_pure_function() {
        let spec = ShardSpec {
            n_shards: 16,
            salt: 0xC0FFEE,
        };
        let direct = spec.assign(1234);
        // interleave unrelated queries: index-stability, no ordering
        let _ = spec.assign(0);
        let _ = spec.assign(999_999);
        assert_eq!(spec.assign(1234), direct);
        assert!(direct < 16);
        // a different salt or shard count is a different function
        let other = ShardSpec {
            n_shards: 16,
            salt: 0xBEEF,
        };
        assert!((0..10_000u32).any(|u| spec.assign(u) != other.assign(u)));
    }

    #[test]
    fn prop_assignment_stable_and_in_range() {
        for_all("shard assignment pure", |rng| {
            let spec = ShardSpec {
                n_shards: rng.below(64) as u32 + 1,
                salt: rng.next_u64(),
            };
            let user = rng.below(1 << 32) as u32;
            let a = spec.assign(user);
            assert!(a < spec.n_shards);
            assert_eq!(spec.assign(user), a, "pure function of inputs");
        });
    }

    #[test]
    fn balanced_within_2x_of_uniform_on_10k_users() {
        for &n_shards in &[2u32, 4, 16] {
            for &salt in &[1u64, 0xDEAD_BEEF, 42] {
                let spec = ShardSpec { n_shards, salt };
                let mut counts = vec![0u64; n_shards as usize];
                for u in 0..10_000u32 {
                    counts[spec.assign(u) as usize] += 1;
                }
                let expected = 10_000 / n_shards as u64;
                for (s, &c) in counts.iter().enumerate() {
                    assert!(
                        c <= 2 * expected && c >= expected / 2,
                        "shard {s}/{n_shards} salt {salt:#x}: {c} users vs \
                         uniform {expected} (outside the 2x band)"
                    );
                }
            }
        }
    }

    #[test]
    fn topology_drift_fails_pins_in_both_directions() {
        let a = ShardSpec {
            n_shards: 4,
            salt: 7,
        };
        let b = ShardSpec {
            n_shards: 8,
            salt: 7,
        };
        let mut pa = base_pins();
        pa.shard = a.pin_for(1);
        let mut pb = base_pins();
        pb.shard = b.pin_for(1);
        // changing n_shards drifts the pin — both directions
        assert!(pa.ensure_match(&pb).is_err());
        assert!(pb.ensure_match(&pa).is_err());
        // a sharded run reopened unsharded (and vice versa) drifts too
        let pu = base_pins();
        assert!(pa.ensure_match(&pu).is_err());
        assert!(pu.ensure_match(&pa).is_err());
        // changing the salt alone drifts
        let mut ps = base_pins();
        ps.shard = ShardSpec {
            n_shards: 4,
            salt: 8,
        }
        .pin_for(1);
        assert!(pa.ensure_match(&ps).is_err());
        // the same topology + index verifies clean
        let mut pa2 = base_pins();
        pa2.shard = a.pin_for(1);
        assert!(pa.ensure_match(&pa2).is_ok());
        // the same topology under a different shard INDEX drifts (a run
        // dir cannot be opened as a different shard)
        let mut pa3 = base_pins();
        pa3.shard = a.pin_for(2);
        assert!(pa.ensure_match(&pa3).is_err());
    }

    #[test]
    fn spec_json_roundtrip() {
        let dir = crate::util::tempdir("shard-spec");
        let spec = ShardSpec {
            n_shards: 12,
            salt: 0xFEED_F00D,
        };
        let path = dir.join("fleet.json");
        spec.save(&path).unwrap();
        assert_eq!(ShardSpec::load(&path).unwrap(), spec);
    }

    #[test]
    fn split_partitions_every_sample_exactly_once() {
        let corpus = Corpus::generate(CorpusConfig {
            n_users: 24,
            docs_per_user: 4,
            n_canary_users: 2,
            canaries_per_user: 2,
            near_dup_rate: 0.1,
            seq_len: 32,
            seed: 9,
        });
        let spec = ShardSpec {
            n_shards: 4,
            salt: 0x51AB,
        };
        let split = split_corpus(&spec, &corpus);
        assert_eq!(split.corpora.len(), 4);
        let total: usize = split.corpora.iter().map(|c| c.len()).sum();
        assert_eq!(total, corpus.len(), "no sample lost or duplicated");
        // derive the local→global view from the locate map
        let mut globals: Vec<Vec<(u64, u64)>> = vec![Vec::new(); 4];
        for (&gid, &(shard, local)) in &split.locate {
            globals[shard as usize].push((local, gid));
        }
        for g in &mut globals {
            g.sort_unstable();
        }
        for (shard, c) in split.corpora.iter().enumerate() {
            assert_eq!(globals[shard].len(), c.len());
            for (i, s) in c.samples.iter().enumerate() {
                // dense local ids, ownership respected
                assert_eq!(s.id, i as u64);
                assert_eq!(spec.assign(s.user), shard as u32);
                // global→local mapping round-trips
                let (local, gid) = globals[shard][i];
                assert_eq!(local, i as u64);
                assert_eq!(split.locate[&gid], (shard as u32, i as u64));
                assert_eq!(corpus.by_id(gid).unwrap().text, s.text);
                // near-dup refs stay resolvable within the shard
                if let SampleKind::NearDup { of } = s.kind {
                    assert!(c.by_id(of).is_some(), "local of-ref resolves");
                }
            }
        }
    }

    #[test]
    fn split_degrades_cross_owner_dup_to_normal() {
        let mut corpus = Corpus::generate(CorpusConfig {
            n_users: 24,
            docs_per_user: 4,
            n_canary_users: 0,
            canaries_per_user: 0,
            near_dup_rate: 0.2,
            seq_len: 32,
            seed: 11,
        });
        let spec = ShardSpec {
            n_shards: 4,
            salt: 0x51AB,
        };
        // move one near-dup to a user on a DIFFERENT shard than its
        // original — the cross-shard scatter scenario
        let (idx, of) = corpus
            .samples
            .iter()
            .enumerate()
            .find_map(|(i, s)| match s.kind {
                SampleKind::NearDup { of } => Some((i, of)),
                _ => None,
            })
            .expect("corpus has near-dups");
        let orig_user = corpus.by_id(of).unwrap().user;
        let other = (0..24u32)
            .find(|&u| spec.assign(u) != spec.assign(orig_user))
            .expect("a user on another shard exists");
        corpus.samples[idx].user = other;
        let gid = corpus.samples[idx].id;

        let split = split_corpus(&spec, &corpus);
        let (shard, local) = split.locate[&gid];
        assert_ne!(shard, spec.assign(orig_user), "dup routed by owner");
        // the dangling back-reference degraded, content preserved
        let s = split.corpora[shard as usize].by_id(local).unwrap();
        assert_eq!(s.kind, SampleKind::Normal);
        assert_eq!(s.text, corpus.by_id(gid).unwrap().text);
    }
}
