//! The training loop proper.

use std::path::PathBuf;

use crate::checkpoint::{CheckpointStore, TrainState};
use crate::config::RunConfig;
use crate::data::corpus::Corpus;
use crate::data::sampler::{DeterministicSampler, Microbatch};
use crate::deltas::{DeltaRing, PatchMode};
use crate::runtime::Runtime;
use crate::util::json::Json;
use crate::wal::{IdMap, WalRecord, WalWriter};

use super::SegmentStage;

/// Everything a finished training run leaves on disk / in memory.
pub struct TrainOutput {
    pub state: TrainState,
    pub ring: DeltaRing,
    pub idmap: IdMap,
    pub losses: Vec<(u32, f32)>, // (logical step, mean loss/token)
    pub wal_dir: PathBuf,
    pub run_dir: PathBuf,
}

/// Deterministic trainer over the AOT runtime.
pub struct Trainer<'rt> {
    pub runtime: &'rt Runtime,
    pub cfg: RunConfig,
    pub corpus: Corpus,
}

impl<'rt> Trainer<'rt> {
    pub fn new(runtime: &'rt Runtime, cfg: RunConfig, corpus: Corpus) -> Self {
        Trainer {
            runtime,
            cfg,
            corpus,
        }
    }

    /// The sampler that defines the logical microbatch graph G.
    pub fn sampler(&self) -> DeterministicSampler {
        DeterministicSampler::new(
            self.corpus.len(),
            self.runtime.manifest.batch,
            self.cfg.accum,
            self.cfg.steps,
            self.cfg.run_seed,
        )
    }

    /// Run the full training program, producing WAL + checkpoints +
    /// delta ring + loss curve.  `filter` masks samples from the very
    /// start (used to build preserved-graph oracle retrains; pass
    /// `|_| false` for normal training).
    pub fn train(
        &self,
        filter: impl Fn(u64) -> bool,
    ) -> anyhow::Result<TrainOutput> {
        self.train_inner(filter, None)
    }

    /// Train with samples *excluded from the dataloader* (they never
    /// enter the microbatch graph or the WAL) — how cohort data is
    /// firewalled before adapter training (G2 workloads).  Distinct from
    /// mask-based filtering, which preserves the graph.
    pub fn train_excluding(
        &self,
        exclude: &std::collections::HashSet<u64>,
    ) -> anyhow::Result<TrainOutput> {
        self.train_inner(|_| false, Some(exclude))
    }

    fn train_inner(
        &self,
        filter: impl Fn(u64) -> bool,
        exclude: Option<&std::collections::HashSet<u64>>,
    ) -> anyhow::Result<TrainOutput> {
        let rt = self.runtime;
        let cfg = &self.cfg;
        let man = &rt.manifest;
        std::fs::create_dir_all(&cfg.run_dir)?;
        let wal_dir = cfg.run_dir.join("wal");
        let mut wal = WalWriter::create(
            &wal_dir,
            cfg.wal_segment_records,
            cfg.hmac_key.clone(),
        )?;
        wal.enable_sidecar()?;
        let mut idmap = IdMap::new(cfg.hmac_key.clone());
        let store =
            CheckpointStore::open(&cfg.run_dir.join("ckpt"), cfg.checkpoint_keep)?;
        let mut ring = DeltaRing::new(
            man.param_count,
            cfg.ring_window,
            PatchMode::Xor,
            cfg.ring_revert_optimizer,
        );

        // θ0 from the AOT artifact; save as the step-0 checkpoint so
        // replay can always reach back to the very beginning.
        let mut state = TrainState::zeros_like(man.init_params()?);
        store.save_full(&state)?;

        // persist run metadata + pins (fail-closed contract for replay);
        // a fleet shard stamps its topology pin so replays under a
        // different user→shard routing fail closed
        let mut pins = rt.capture_pins(cfg.accum);
        pins.shard = cfg.shard_pin.clone();
        pins.save(&cfg.run_dir.join("pins.json"))?;
        std::fs::write(
            cfg.run_dir.join("run_config.json"),
            cfg.to_json().pretty(),
        )?;

        let mut schedule = self.sampler().schedule();
        if let Some(ex) = exclude {
            // dataloader-level exclusion: ids vanish from the graph
            for mb in &mut schedule {
                mb.sample_ids.retain(|id| !ex.contains(id));
            }
        }
        let mut losses = Vec::new();
        // The current accumulation segment, staged record by record and
        // executed as ONE batched `grad_accumulate` call at `accum_end`
        // — the same staging layer AND entry point (pinned combine
        // order, Lemma A.3) replay traverses, so train and replay
        // cannot drift.
        let mut seg = SegmentStage::new();

        for mb in &schedule {
            let lr = cfg.lr_at(state.applied_updates);
            self.log_record(&mut wal, &mut idmap, mb, lr)?;
            seg.stage(
                &self.corpus,
                &mb.sample_ids,
                man.batch,
                man.seq_len,
                &filter,
                false,
                mb.seed64 as i32,
            )?;
            if mb.accum_end {
                let inputs = seg.inputs();
                if !inputs.is_empty() {
                    let out = rt.grad_accumulate(&state.params, &inputs)?;
                    let step_before = state.logical_step;
                    let (p, m, v) = rt.adamw_update(
                        &state.params,
                        &out.grad,
                        &state.m,
                        &state.v,
                        state.applied_updates as i32 + 1,
                        lr,
                    )?;
                    // hand the pre-update tensors to the ring instead of
                    // cloning the full TrainState every step
                    let before_params = std::mem::replace(&mut state.params, p);
                    let before_m = std::mem::replace(&mut state.m, m);
                    let before_v = std::mem::replace(&mut state.v, v);
                    state.applied_updates += 1;
                    state.logical_step = mb.step + 1;
                    ring.record_parts(
                        step_before,
                        &before_params,
                        &before_m,
                        &before_v,
                        &state,
                    )?;
                    if out.tok_count > 0.0 {
                        losses.push((mb.step, out.loss_sum / out.tok_count));
                    }
                } else {
                    // empty-step skip (Prop. A.5): no counter advance
                    state.logical_step = mb.step + 1;
                }
                seg.reset();

                let done = mb.step + 1;
                if cfg.checkpoint_every > 0 && done % cfg.checkpoint_every == 0
                {
                    store.save_full(&state)?;
                }
                if cfg.micro_checkpoint_every > 0
                    && done % cfg.micro_checkpoint_every == 0
                {
                    store.save_micro(&state)?;
                }
            }
        }

        // final checkpoint + artifacts
        store.save_full(&state)?;
        idmap.save(&cfg.run_dir.join("ids.map"))?;
        wal.finish()?;
        self.write_losses(&losses)?;
        Ok(TrainOutput {
            state,
            ring,
            idmap,
            losses,
            wal_dir,
            run_dir: cfg.run_dir.clone(),
        })
    }

    fn log_record(
        &self,
        wal: &mut WalWriter,
        idmap: &mut IdMap,
        mb: &Microbatch,
        lr: f32,
    ) -> anyhow::Result<()> {
        let hash64 = idmap.register(&mb.sample_ids);
        wal.append(&WalRecord {
            hash64,
            seed64: mb.seed64,
            lr_bits: lr.to_bits(),
            opt_step: mb.step,
            accum_end: mb.accum_end,
            mb_len: mb.sample_ids.len() as u16,
        })
    }

    fn write_losses(&self, losses: &[(u32, f32)]) -> anyhow::Result<()> {
        let mut csv = String::from("step,loss_per_token\n");
        for (s, l) in losses {
            csv.push_str(&format!("{s},{l}\n"));
        }
        std::fs::write(self.cfg.run_dir.join("losses.csv"), csv)?;
        let mut j = Json::obj();
        j.set(
            "losses",
            Json::Arr(
                losses
                    .iter()
                    .map(|(s, l)| {
                        let mut o = Json::obj();
                        o.set("step", *s).set("loss_per_token", *l);
                        o
                    })
                    .collect(),
            ),
        );
        std::fs::write(self.cfg.run_dir.join("losses.json"), j.encode())?;
        Ok(())
    }
}
