//! Deterministic trainer (paper §4.1): the training program Π whose
//! control inputs are fully logged.
//!
//! Per microbatch it (1) registers the ordered sample IDs in the IdMap,
//! (2) appends the 32-byte WAL record (Alg. A.1), (3) stages the
//! microbatch tensors into the current accumulation segment.  At each
//! accumulation boundary the staged segment runs as ONE
//! `Runtime::grad_accumulate` call — per-microbatch gradients combined
//! in the explicit, logged order (the pinned reduce; Lemma A.3), the
//! same batched entry point replay traverses — then the fused AdamW
//! update applies with the *logged* LR value, the per-step delta is
//! recorded in the ring buffer, and checkpoints are taken on the
//! configured cadence.

pub mod loop_;

pub use loop_::{TrainOutput, Trainer};

use crate::data::corpus::Corpus;
use crate::runtime::MicrobatchInput;

/// Staged tensors of one microbatch within the current accumulation
/// segment.
#[derive(Default)]
pub struct SegSlot {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub seed: i32,
    pub retained: bool,
}

/// The current accumulation segment, staged record by record and
/// executed as ONE `Runtime::grad_accumulate` call at the boundary.
/// Slot buffers are reused across segments (no per-record allocation).
/// Shared by the trainer and replay so the staging layer — like the
/// batched entry point itself — cannot drift between them.
#[derive(Default)]
pub struct SegmentStage {
    slots: Vec<SegSlot>,
    len: usize,
}

impl SegmentStage {
    pub fn new() -> SegmentStage {
        SegmentStage::default()
    }

    /// Stage one record's tensors into the next slot (growing the slot
    /// pool on first use); returns the retained-sample count.
    #[allow(clippy::too_many_arguments)]
    pub fn stage(
        &mut self,
        corpus: &Corpus,
        ids: &[u64],
        batch: usize,
        seq_len: usize,
        filter: impl Fn(u64) -> bool,
        zero_content: bool,
        seed: i32,
    ) -> anyhow::Result<usize> {
        if self.len == self.slots.len() {
            self.slots.push(SegSlot::default());
        }
        let slot = &mut self.slots[self.len];
        let retained = build_microbatch_tensors_into(
            corpus,
            ids,
            batch,
            seq_len,
            filter,
            zero_content,
            &mut slot.tokens,
            &mut slot.mask,
        )?;
        slot.seed = seed;
        slot.retained = retained > 0;
        self.len += 1;
        Ok(retained)
    }

    /// The retained microbatches of the staged segment, in record
    /// order — the pinned combine order of `grad_accumulate`.
    pub fn inputs(&self) -> Vec<MicrobatchInput<'_>> {
        self.slots[..self.len]
            .iter()
            .filter(|s| s.retained)
            .map(|s| MicrobatchInput {
                tokens: &s.tokens,
                mask: &s.mask,
                seed: s.seed,
            })
            .collect()
    }

    /// Start the next segment (slot buffers are kept for reuse).
    pub fn reset(&mut self) {
        self.len = 0;
    }
}

/// Build the padded `[batch, seq_len]` token tensor + per-example mask
/// for an ordered ID list.  Slots beyond `ids.len()` are PAD + mask 0.
/// If `filter(id)` is true the slot's mask is forced to 0; with
/// `zero_content` its *content* is scrubbed too (all-PAD) — used by
/// content-scrubbed replay (bitwise content-independence makes this
/// exact; see `python/tests/test_model.py::
/// test_mask_content_independence_bitwise`).
pub fn build_microbatch_tensors(
    corpus: &Corpus,
    ids: &[u64],
    batch: usize,
    seq_len: usize,
    filter: impl Fn(u64) -> bool,
    zero_content: bool,
) -> anyhow::Result<(Vec<i32>, Vec<f32>, usize)> {
    let mut tokens = Vec::new();
    let mut mask = Vec::new();
    let retained = build_microbatch_tensors_into(
        corpus,
        ids,
        batch,
        seq_len,
        filter,
        zero_content,
        &mut tokens,
        &mut mask,
    )?;
    Ok((tokens, mask, retained))
}

/// [`build_microbatch_tensors`] into caller-owned buffers, cleared and
/// resized in place — the trainer and replay loops reuse one pair of
/// buffers across the whole WAL traversal instead of allocating two
/// fresh vectors per microbatch record.
#[allow(clippy::too_many_arguments)]
pub fn build_microbatch_tensors_into(
    corpus: &Corpus,
    ids: &[u64],
    batch: usize,
    seq_len: usize,
    filter: impl Fn(u64) -> bool,
    zero_content: bool,
    tokens: &mut Vec<i32>,
    mask: &mut Vec<f32>,
) -> anyhow::Result<usize> {
    anyhow::ensure!(ids.len() <= batch, "microbatch larger than batch dim");
    tokens.clear();
    tokens.resize(batch * seq_len, 0);
    mask.clear();
    mask.resize(batch, 0.0);
    let mut retained = 0usize;
    for (slot, &id) in ids.iter().enumerate() {
        if filter(id) {
            // filtered: mask stays 0; content scrubbed if requested
            if !zero_content {
                let s = corpus
                    .by_id(id)
                    .ok_or_else(|| anyhow::anyhow!("unknown sample {id}"))?;
                tokens[slot * seq_len..(slot + 1) * seq_len]
                    .copy_from_slice(&s.tokens);
            }
        } else {
            let s = corpus
                .by_id(id)
                .ok_or_else(|| anyhow::anyhow!("unknown sample {id}"))?;
            anyhow::ensure!(s.tokens.len() == seq_len, "token length");
            tokens[slot * seq_len..(slot + 1) * seq_len]
                .copy_from_slice(&s.tokens);
            mask[slot] = 1.0;
            retained += 1;
        }
    }
    Ok(retained)
}

/// Deterministic in-place gradient accumulation: `acc += g`, sequential
/// element order (the explicit, logged summation order of Lemma A.3).
pub fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, x) in acc.iter_mut().zip(g) {
        *a += x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    fn corpus() -> Corpus {
        Corpus::generate(CorpusConfig {
            n_users: 4,
            docs_per_user: 2,
            n_canary_users: 0,
            canaries_per_user: 0,
            near_dup_rate: 0.0,
            seq_len: 16,
            seed: 1,
        })
    }

    #[test]
    fn tensors_pad_and_mask() {
        let c = corpus();
        let (tokens, mask, retained) =
            build_microbatch_tensors(&c, &[0, 1, 2], 4, 16, |_| false, false)
                .unwrap();
        assert_eq!(tokens.len(), 64);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 0.0]);
        assert_eq!(retained, 3);
        assert!(tokens[48..].iter().all(|&t| t == 0)); // padded slot
    }

    #[test]
    fn filtering_masks_and_scrubs() {
        let c = corpus();
        let (tokens, mask, retained) =
            build_microbatch_tensors(&c, &[0, 1], 2, 16, |id| id == 0, true)
                .unwrap();
        assert_eq!(mask, vec![0.0, 1.0]);
        assert_eq!(retained, 1);
        assert!(tokens[..16].iter().all(|&t| t == 0), "content scrubbed");
        assert_eq!(&tokens[16..32], c.by_id(1).unwrap().tokens.as_slice());
    }

    #[test]
    fn filtering_without_scrub_keeps_content() {
        let c = corpus();
        let (tokens, mask, _) =
            build_microbatch_tensors(&c, &[0, 1], 2, 16, |id| id == 0, false)
                .unwrap();
        assert_eq!(mask[0], 0.0);
        assert_eq!(&tokens[..16], c.by_id(0).unwrap().tokens.as_slice());
    }

    #[test]
    fn accumulate_is_elementwise_sum() {
        let mut acc = vec![1.0f32, 2.0, 3.0];
        accumulate(&mut acc, &[0.5, -2.0, 1.0]);
        assert_eq!(acc, vec![1.5, 0.0, 4.0]);
    }
}
