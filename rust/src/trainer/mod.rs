//! Deterministic trainer (paper §4.1): the training program Π whose
//! control inputs are fully logged.
//!
//! Per microbatch it (1) registers the ordered sample IDs in the IdMap,
//! (2) appends the 32-byte WAL record (Alg. A.1), (3) executes the
//! `train_step` graph, (4) accumulates gradients in an explicit,
//! logged order.  At each accumulation boundary it applies the fused
//! AdamW update with the *logged* LR value, records the per-step delta
//! in the ring buffer, and takes checkpoints on the configured cadence.

pub mod loop_;

pub use loop_::{TrainOutput, Trainer};

use crate::data::corpus::Corpus;

/// Build the padded `[batch, seq_len]` token tensor + per-example mask
/// for an ordered ID list.  Slots beyond `ids.len()` are PAD + mask 0.
/// If `filter(id)` is true the slot's mask is forced to 0; with
/// `zero_content` its *content* is scrubbed too (all-PAD) — used by
/// content-scrubbed replay (bitwise content-independence makes this
/// exact; see `python/tests/test_model.py::
/// test_mask_content_independence_bitwise`).
pub fn build_microbatch_tensors(
    corpus: &Corpus,
    ids: &[u64],
    batch: usize,
    seq_len: usize,
    filter: impl Fn(u64) -> bool,
    zero_content: bool,
) -> anyhow::Result<(Vec<i32>, Vec<f32>, usize)> {
    let mut tokens = Vec::new();
    let mut mask = Vec::new();
    let retained = build_microbatch_tensors_into(
        corpus,
        ids,
        batch,
        seq_len,
        filter,
        zero_content,
        &mut tokens,
        &mut mask,
    )?;
    Ok((tokens, mask, retained))
}

/// [`build_microbatch_tensors`] into caller-owned buffers, cleared and
/// resized in place — the trainer and replay loops reuse one pair of
/// buffers across the whole WAL traversal instead of allocating two
/// fresh vectors per microbatch record.
#[allow(clippy::too_many_arguments)]
pub fn build_microbatch_tensors_into(
    corpus: &Corpus,
    ids: &[u64],
    batch: usize,
    seq_len: usize,
    filter: impl Fn(u64) -> bool,
    zero_content: bool,
    tokens: &mut Vec<i32>,
    mask: &mut Vec<f32>,
) -> anyhow::Result<usize> {
    anyhow::ensure!(ids.len() <= batch, "microbatch larger than batch dim");
    tokens.clear();
    tokens.resize(batch * seq_len, 0);
    mask.clear();
    mask.resize(batch, 0.0);
    let mut retained = 0usize;
    for (slot, &id) in ids.iter().enumerate() {
        if filter(id) {
            // filtered: mask stays 0; content scrubbed if requested
            if !zero_content {
                let s = corpus
                    .by_id(id)
                    .ok_or_else(|| anyhow::anyhow!("unknown sample {id}"))?;
                tokens[slot * seq_len..(slot + 1) * seq_len]
                    .copy_from_slice(&s.tokens);
            }
        } else {
            let s = corpus
                .by_id(id)
                .ok_or_else(|| anyhow::anyhow!("unknown sample {id}"))?;
            anyhow::ensure!(s.tokens.len() == seq_len, "token length");
            tokens[slot * seq_len..(slot + 1) * seq_len]
                .copy_from_slice(&s.tokens);
            mask[slot] = 1.0;
            retained += 1;
        }
    }
    Ok(retained)
}

/// Deterministic in-place gradient accumulation: `acc += g`, sequential
/// element order (the explicit, logged summation order of Lemma A.3).
pub fn accumulate(acc: &mut [f32], g: &[f32]) {
    debug_assert_eq!(acc.len(), g.len());
    for (a, x) in acc.iter_mut().zip(g) {
        *a += x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    fn corpus() -> Corpus {
        Corpus::generate(CorpusConfig {
            n_users: 4,
            docs_per_user: 2,
            n_canary_users: 0,
            canaries_per_user: 0,
            near_dup_rate: 0.0,
            seq_len: 16,
            seed: 1,
        })
    }

    #[test]
    fn tensors_pad_and_mask() {
        let c = corpus();
        let (tokens, mask, retained) =
            build_microbatch_tensors(&c, &[0, 1, 2], 4, 16, |_| false, false)
                .unwrap();
        assert_eq!(tokens.len(), 64);
        assert_eq!(mask, vec![1.0, 1.0, 1.0, 0.0]);
        assert_eq!(retained, 3);
        assert!(tokens[48..].iter().all(|&t| t == 0)); // padded slot
    }

    #[test]
    fn filtering_masks_and_scrubs() {
        let c = corpus();
        let (tokens, mask, retained) =
            build_microbatch_tensors(&c, &[0, 1], 2, 16, |id| id == 0, true)
                .unwrap();
        assert_eq!(mask, vec![0.0, 1.0]);
        assert_eq!(retained, 1);
        assert!(tokens[..16].iter().all(|&t| t == 0), "content scrubbed");
        assert_eq!(&tokens[16..32], c.by_id(1).unwrap().tokens.as_slice());
    }

    #[test]
    fn filtering_without_scrub_keeps_content() {
        let c = corpus();
        let (tokens, mask, _) =
            build_microbatch_tensors(&c, &[0, 1], 2, 16, |id| id == 0, false)
                .unwrap();
        assert_eq!(mask[0], 0.0);
        assert_eq!(&tokens[..16], c.by_id(0).unwrap().tokens.as_slice());
    }

    #[test]
    fn accumulate_is_elementwise_sum() {
        let mut acc = vec![1.0f32, 2.0, 3.0];
        accumulate(&mut acc, &[0.5, -2.0, 1.0]);
        assert_eq!(acc, vec![1.5, 0.0, 4.0]);
    }
}
