//! # Unlearning at Scale — Rust coordinator (Layer 3)
//!
//! Production-shaped implementation of *"Unlearning at Scale: Implementing
//! the Right to be Forgotten in Large Language Models"*: training as a
//! deterministic, write-ahead-logged program so that exact unlearning is
//! constructive (`ReplayFilter`), plus the paper's operational fast paths
//! (dense per-step delta reverts, cohort-scoped adapter deletion,
//! curvature-guided audited anti-update) routed by a controller that
//! appends every action to a signed forget manifest.
//!
//! The compute graphs (model fwd/bwd, fused AdamW) run through one of
//! two interchangeable backends: the default deterministic pure-Rust
//! reference executor (hermetic tier-1, no native deps), or — behind
//! the `pjrt` cargo feature — the JAX/Pallas programs AOT-lowered to
//! HLO text (`make artifacts`) and executed through the `xla` crate's
//! PJRT CPU client.  Python never runs on the request path either way.
//!
//! Module map (see DESIGN.md for the paper-section correspondence and
//! the hot-path performance architecture):
//! - [`runtime`]    open `Executor` trait API (reference / PJRT) +
//!                  batch-first entry points + fingerprint pins
//! - [`wal`]        32-byte microbatch write-ahead log (Def. 1)
//! - [`trainer`]    deterministic trainer + scheduler (§4.1)
//! - [`replay`]     `ReplayFilter` (Alg. A.9)
//! - [`checkpoint`] full/micro checkpoint store
//! - [`deltas`]     dense per-step delta ring buffer (G3, Alg. A.3)
//! - [`adapters`]   cohort-scoped LoRA registry (G2, Alg. A.5)
//! - [`curvature`]  diag-Fisher cache + anti-update hot path (Alg. A.4)
//! - [`neardup`]    SimHash near-duplicate index + closure (Alg. A.6),
//!                  with per-member document-ownership attribution
//! - [`ingest`]     online ingest: durable doc segments + bounded
//!                  train-increments committed through a deterministic
//!                  interleave log (train-and-forget concurrently)
//! - [`shard`]      pinned deterministic user→shard partitioning
//! - [`fleet`]      N-shard orchestrator: ownership routing, parallel
//!                  cross-shard execution, fleet planning/eval/serving
//! - [`replica`]    serving data plane: lineage-synced read replicas
//!                  (CAS pull by generation, watermarked query plane,
//!                  erasure-propagation SLA)
//! - [`audit`]      MIA / canary exposure / extraction / fuzzy / utility
//! - [`controller`] path-selection policy (Alg. A.7)
//! - [`manifest`]   signed, hash-chained forget manifest
//! - [`cigate`]     determinism/replay CI gate (Alg. 5.1)
//! - [`lint`]       `detlint` static conformance analyzer (token lexer
//!                  + determinism/durability rules + allow policy)
//! - [`equality`]   equality-proof artifact (Table 5)
//! - [`data`]       tokenizer, synthetic corpus, deterministic sampler
//! - [`server`]     TCP/JSON admin server for forget requests
//! - [`config`]     run configuration + reproducibility pins (Table 2)
//! - [`util`]       hashing, JSON, RNG, compression, zero-copy byte
//!                  layer (`util::simd`), CLI, property testing

pub mod adapters;
pub mod audit;
pub mod checkpoint;
pub mod cigate;
pub mod config;
pub mod controller;
pub mod curvature;
pub mod data;
pub mod deltas;
pub mod equality;
pub mod fleet;
pub mod ingest;
pub mod lint;
pub mod manifest;
pub mod metrics;
pub mod neardup;
pub mod replay;
pub mod replica;
pub mod runtime;
pub mod server;
pub mod shard;
pub mod trainer;
pub mod util;
pub mod wal;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
pub mod harness;
