//! Lightweight metrics: counters and wall-clock timers for the trainer,
//! replay and controller (exported into EXPERIMENTS.md and bench output).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::Json;

/// The repo's single sanctioned monotonic-clock read (this module is
/// the detlint wall-clock allowlist).  Timeout/deadline arithmetic in
/// the admin-plane event loop and the worker's coalescing wait goes
/// through here so clock reads stay auditable in one place; the values
/// never reach serialized or replayed state.
pub fn monotonic_now() -> Instant {
    Instant::now()
}

/// A registry of named counters and timing accumulators.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    timers: BTreeMap<String, (u64, f64)>, // (count, total seconds)
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn inc(&self, name: &str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner
            .lock()
            .unwrap()
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Time a closure under a named timer.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.record_secs(name, start.elapsed().as_secs_f64());
        out
    }

    pub fn record_secs(&self, name: &str, secs: f64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.timers.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }

    /// (count, total secs, mean secs) for a timer.
    pub fn timer(&self, name: &str) -> Option<(u64, f64, f64)> {
        let g = self.inner.lock().unwrap();
        g.timers
            .get(name)
            .map(|&(n, tot)| (n, tot, if n > 0 { tot / n as f64 } else { 0.0 }))
    }

    /// All timers as (name, count, total secs), sorted by name — lets
    /// the bench reporters dump every `exec.*` graph timer without
    /// hardcoding graph names.
    pub fn timers(&self) -> Vec<(String, u64, f64)> {
        let g = self.inner.lock().unwrap();
        g.timers
            .iter()
            .map(|(k, &(n, tot))| (k.clone(), n, tot))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut counters = Json::obj();
        for (k, v) in &g.counters {
            counters.set(k, *v);
        }
        let mut timers = Json::obj();
        for (k, &(n, tot)) in &g.timers {
            let mut t = Json::obj();
            t.set("count", n).set("total_s", tot).set(
                "mean_s",
                if n > 0 { tot / n as f64 } else { 0.0 },
            );
            timers.set(k, t);
        }
        let mut j = Json::obj();
        j.set("counters", counters).set("timers", timers);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.inc("steps", 1);
        m.inc("steps", 2);
        assert_eq!(m.counter("steps"), 3);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timers_record() {
        let m = Metrics::new();
        let v = m.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        let (n, tot, mean) = m.timer("work").unwrap();
        assert_eq!(n, 1);
        assert!(tot >= 0.004 && mean >= 0.004);
    }

    #[test]
    fn json_export() {
        let m = Metrics::new();
        m.inc("a", 1);
        m.record_secs("t", 0.5);
        let j = m.to_json();
        assert_eq!(j.get_path(&["counters", "a"]).unwrap().as_u64(), Some(1));
        assert!(j.get_path(&["timers", "t", "mean_s"]).is_some());
    }
}
