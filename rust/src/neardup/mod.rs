//! Near-duplicate detection and forget-closure expansion (paper §4.3,
//! Alg. A.6): SimHash over token shingles (Manku et al.) with a banded
//! Hamming index (the ANN role FAISS plays in the paper), and the
//! fixed-point closure expansion `cl(F)`.

pub mod closure;
pub mod index;
pub mod simhash;

pub use closure::{expand_closure, ClosureParams, ClosureResult};
pub use index::HammingIndex;
pub use simhash::{simhash_tokens, hamming, jaccard_shingles};
