//! Fixed-point forget-closure expansion `cl(F)` (paper Alg. A.6).
//!
//! BFS from the requested samples: SimHash + banded index propose
//! candidates (`|h(y) ⊕ q| ≤ τ_h`), exact shingle-Jaccard confirms
//! (`Similarity(x,y) ≥ τ_sim`), newly admitted members are pushed back
//! onto the queue until a fixed point is reached.

use std::collections::{HashSet, VecDeque};

use crate::data::corpus::Corpus;

use super::index::HammingIndex;
use super::simhash::{jaccard_shingles, simhash_tokens};

/// Thresholds (τ_h, τ_sim) of Alg. A.6.
#[derive(Debug, Clone, Copy)]
pub struct ClosureParams {
    /// Max Hamming distance between SimHash signatures.
    pub tau_hamming: u32,
    /// Min exact Jaccard similarity over token shingles.
    pub tau_sim: f64,
}

impl Default for ClosureParams {
    fn default() -> Self {
        // word-bigram SimHash on short documents: near-dups measured at
        // distance 9-17, unrelated at 29+ (see simhash.rs tests), so 20
        // separates them with margin.  Jaccard confirm at 0.6: the
        // corpus's true paraphrase families land at >= 0.7 bigram
        // Jaccard, while *cross-user* docs sharing a sentence template
        // peak around 0.4-0.5 — 0.6 cleanly separates them.
        ClosureParams {
            tau_hamming: 20,
            tau_sim: 0.6,
        }
    }
}

/// Closure output: the expanded ID set plus audit bookkeeping.
#[derive(Debug, Clone)]
pub struct ClosureResult {
    /// cl(F): requested IDs plus admitted near-duplicates, sorted.
    pub ids: Vec<u64>,
    /// IDs admitted by expansion (excluding the original request).
    pub expanded: Vec<u64>,
    /// Document ownership of every resolvable closure member, sorted by
    /// id (aligned with `ids` minus unresolvable request IDs).  A
    /// closure spanning multiple owners — a near-dup of user `u`'s doc
    /// owned by user `v` — reports both, so callers (the fleet router
    /// scattering a closure across shards, audit attribution) no longer
    /// re-derive ownership from the corpus.
    pub owners: Vec<(u64, u32)>,
    /// BFS rounds until fixed point.
    pub rounds: usize,
}

impl ClosureResult {
    pub fn contains(&self, id: u64) -> bool {
        self.ids.binary_search(&id).is_ok()
    }

    pub fn id_set(&self) -> HashSet<u64> {
        self.ids.iter().copied().collect()
    }

    /// Owning user of a closure member (None for an id that is not in
    /// the closure or did not resolve against the corpus).
    pub fn owner_of(&self, id: u64) -> Option<u32> {
        self.owners
            .binary_search_by_key(&id, |&(i, _)| i)
            .ok()
            .map(|k| self.owners[k].1)
    }

    /// Closure members grouped by owning user, users ascending, each
    /// group's ids sorted — the fleet router's scatter unit.
    pub fn by_owner(&self) -> Vec<(u32, Vec<u64>)> {
        let mut groups: std::collections::BTreeMap<u32, Vec<u64>> =
            std::collections::BTreeMap::new();
        for &(id, user) in &self.owners {
            groups.entry(user).or_default().push(id);
        }
        groups.into_iter().collect()
    }

    /// Distinct owning users, ascending.
    pub fn owner_users(&self) -> Vec<u32> {
        let mut users: Vec<u32> =
            self.owners.iter().map(|&(_, u)| u).collect();
        users.sort_unstable();
        users.dedup();
        users
    }
}

/// Build a SimHash index over the whole corpus (the "near-dup index"
/// artifact of Table 1; refreshed continuously in production).
pub fn build_index(corpus: &Corpus) -> HammingIndex {
    let mut idx = HammingIndex::new();
    for s in &corpus.samples {
        idx.insert(s.id, simhash_tokens(&s.tokens));
    }
    idx
}

/// Expand `request` to its near-duplicate closure (Alg. A.6).
pub fn expand_closure(
    corpus: &Corpus,
    index: &HammingIndex,
    request: &[u64],
    params: ClosureParams,
) -> ClosureResult {
    let mut members: HashSet<u64> = request.iter().copied().collect();
    let mut queue: VecDeque<u64> = request.iter().copied().collect();
    let mut rounds = 0usize;

    while let Some(x) = queue.pop_front() {
        rounds += 1;
        let Some(xs) = corpus.by_id(x) else { continue };
        let q = index.signature(x).unwrap_or_else(|| simhash_tokens(&xs.tokens));
        for y in index.query(q, params.tau_hamming) {
            if members.contains(&y) {
                continue;
            }
            let Some(ys) = corpus.by_id(y) else { continue };
            if jaccard_shingles(&xs.tokens, &ys.tokens) >= params.tau_sim {
                members.insert(y);
                queue.push_back(y);
            }
        }
    }

    let mut ids: Vec<u64> = members.into_iter().collect();
    ids.sort_unstable();
    let req: HashSet<u64> = request.iter().copied().collect();
    let expanded = ids.iter().copied().filter(|i| !req.contains(i)).collect();
    // ownership attribution (ids are sorted, so owners stay sorted too)
    let owners = ids
        .iter()
        .filter_map(|&id| corpus.by_id(id).map(|s| (id, s.user)))
        .collect();
    ClosureResult {
        ids,
        expanded,
        owners,
        rounds,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusConfig, SampleKind};

    fn corpus() -> Corpus {
        Corpus::generate(CorpusConfig::default())
    }

    #[test]
    fn closure_contains_request() {
        let c = corpus();
        let idx = build_index(&c);
        let req = c.user_samples(0);
        let cl = expand_closure(&c, &idx, &req, ClosureParams::default());
        for id in &req {
            assert!(cl.contains(*id));
        }
    }

    #[test]
    fn closure_pulls_in_near_duplicates() {
        let c = corpus();
        let idx = build_index(&c);
        // find a sample that has an emitted near-dup
        let (dup_id, orig_id) = c
            .samples
            .iter()
            .find_map(|s| match s.kind {
                SampleKind::NearDup { of } => Some((s.id, of)),
                _ => None,
            })
            .expect("corpus has near-dups");
        let cl = expand_closure(&c, &idx, &[orig_id], ClosureParams::default());
        assert!(
            cl.contains(dup_id),
            "requesting {orig_id} must pull in its near-dup {dup_id}"
        );
        assert!(!cl.expanded.is_empty());
    }

    #[test]
    fn closure_is_symmetric_via_fixed_point() {
        // requesting the DUP must also pull in the ORIGINAL
        let c = corpus();
        let idx = build_index(&c);
        let (dup_id, orig_id) = c
            .samples
            .iter()
            .find_map(|s| match s.kind {
                SampleKind::NearDup { of } => Some((s.id, of)),
                _ => None,
            })
            .unwrap();
        let cl = expand_closure(&c, &idx, &[dup_id], ClosureParams::default());
        assert!(cl.contains(orig_id));
    }

    #[test]
    fn closure_is_idempotent() {
        let c = corpus();
        let idx = build_index(&c);
        let req = c.user_samples(1);
        let cl1 = expand_closure(&c, &idx, &req, ClosureParams::default());
        let cl2 = expand_closure(&c, &idx, &cl1.ids, ClosureParams::default());
        assert_eq!(cl1.ids, cl2.ids, "cl(cl(F)) == cl(F)");
    }

    #[test]
    fn strict_thresholds_admit_nothing() {
        let c = corpus();
        let idx = build_index(&c);
        let req = vec![0u64];
        let cl = expand_closure(
            &c,
            &idx,
            &req,
            ClosureParams {
                tau_hamming: 0,
                tau_sim: 1.0,
            },
        );
        // only exact-duplicate tokens would be admitted
        for id in &cl.expanded {
            assert_eq!(c.by_id(*id).unwrap().tokens, c.by_id(0).unwrap().tokens);
        }
    }

    #[test]
    fn closure_carries_document_ownership() {
        let c = corpus();
        let idx = build_index(&c);
        let req = c.user_samples(1);
        let cl = expand_closure(&c, &idx, &req, ClosureParams::default());
        // every member's owner is reported, matching the corpus
        assert_eq!(cl.owners.len(), cl.ids.len());
        for &(id, user) in &cl.owners {
            assert_eq!(c.by_id(id).unwrap().user, user);
            assert_eq!(cl.owner_of(id), Some(user));
        }
        // the grouped view partitions the closure exactly
        let grouped: usize =
            cl.by_owner().iter().map(|(_, ids)| ids.len()).sum();
        assert_eq!(grouped, cl.ids.len());
        assert!(cl.owner_users().contains(&1));
        assert_eq!(cl.owner_of(u64::MAX), None);
    }

    #[test]
    fn cross_owner_expansion_reports_every_owner() {
        // a near-dup re-owned by a DIFFERENT user: requesting the
        // original must report the dup under ITS owner — callers no
        // longer have to re-derive which user (hence which fleet shard)
        // each expanded id belongs to
        let mut c = corpus();
        let (dup_id, orig_id) = c
            .samples
            .iter()
            .find_map(|s| match s.kind {
                SampleKind::NearDup { of } => Some((s.id, of)),
                _ => None,
            })
            .expect("corpus has near-dups");
        let orig_user = c.by_id(orig_id).unwrap().user;
        let other_user = orig_user + 101; // distinct, still valid u32
        c.samples[dup_id as usize].user = other_user;
        let idx = build_index(&c);
        let cl =
            expand_closure(&c, &idx, &[orig_id], ClosureParams::default());
        assert!(cl.contains(dup_id));
        assert_eq!(cl.owner_of(orig_id), Some(orig_user));
        assert_eq!(cl.owner_of(dup_id), Some(other_user));
        let users = cl.owner_users();
        assert!(users.contains(&orig_user) && users.contains(&other_user));
        let by_owner = cl.by_owner();
        assert!(by_owner
            .iter()
            .any(|(u, ids)| *u == other_user && ids.contains(&dup_id)));
    }

    #[test]
    fn incremental_insert_matches_batch_rebuild() {
        // the online-ingest property: a near-dup index grown
        // insert-as-you-go (exactly what `ingest::grow_corpus` does
        // while the system serves) answers every closure identically to
        // a from-scratch rebuild over the final corpus — including over
        // seeded adversarial paraphrases engineered to sit near the τ
        // thresholds (suffix padding, doubled whitespace, prefix notes,
        // cross-user re-owning).
        crate::util::prop::for_all("incremental == batch neardup", |rng| {
            let mut c = Corpus::generate(CorpusConfig {
                n_users: 8,
                docs_per_user: 3,
                n_canary_users: 1,
                canaries_per_user: 1,
                near_dup_rate: 0.2,
                seq_len: 64,
                seed: rng.next_u64(),
            });
            let mut live = build_index(&c);
            let rounds = 1 + rng.below(4);
            for _ in 0..rounds {
                let mut docs = Vec::new();
                for _ in 0..1 + rng.below(3) {
                    let src =
                        &c.samples[rng.below(c.len() as u64) as usize];
                    let text = match rng.below(4) {
                        0 => format!("{} indeed.", src.text),
                        1 => src.text.replacen(' ', "  ", 1),
                        2 => format!("note: {}", src.text),
                        _ => format!(
                            "an unrelated aside numbered {}",
                            rng.next_u64()
                        ),
                    };
                    let user = if rng.below(2) == 0 {
                        src.user
                    } else {
                        300 + rng.below(8) as u32
                    };
                    docs.push(crate::ingest::IngestDoc { user, text });
                }
                let base = c.len() as u64;
                crate::ingest::grow_corpus(&mut c, &mut live, base, &docs)
                    .unwrap();
            }
            let batch = build_index(&c);
            assert_eq!(live.len(), batch.len());
            for s in &c.samples {
                assert_eq!(live.signature(s.id), batch.signature(s.id));
            }
            // single-id closures answer identically
            for _ in 0..4 {
                let id = rng.below(c.len() as u64);
                let a =
                    expand_closure(&c, &live, &[id], ClosureParams::default());
                let b = expand_closure(
                    &c,
                    &batch,
                    &[id],
                    ClosureParams::default(),
                );
                assert_eq!(a.ids, b.ids, "closure of {id} diverges");
            }
            // and a whole-user request (the forget shape)
            let u = c.samples[rng.below(c.len() as u64) as usize].user;
            let req = c.user_samples(u);
            let a =
                expand_closure(&c, &live, &req, ClosureParams::default());
            let b =
                expand_closure(&c, &batch, &req, ClosureParams::default());
            assert_eq!(a.ids, b.ids);
        });
    }

    #[test]
    fn empty_request_empty_closure() {
        let c = corpus();
        let idx = build_index(&c);
        let cl = expand_closure(&c, &idx, &[], ClosureParams::default());
        assert!(cl.ids.is_empty());
        assert_eq!(cl.rounds, 0);
    }
}
