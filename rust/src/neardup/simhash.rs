//! 64-bit SimHash over token shingles (Manku et al., WWW'07).
//!
//! Each document is shingled into overlapping token n-grams; every
//! shingle votes its hash bits with weight +1/-1, the sign vector is
//! collapsed to 64 bits.  Near-duplicates land within a small Hamming
//! distance.

use crate::util::hashing::xxh64;

/// Shingle width (token n-gram length).
pub const SHINGLE: usize = 4;

/// SimHash of a token sequence.
///
/// Features are *word-level* bigrams: the byte-token stream is segmented
/// at spaces/PAD and consecutive word pairs are hashed.  Word features
/// are position-independent, so a single inserted word perturbs only the
/// two bigrams touching the edit — which is what makes near-duplicates
/// land within a small Hamming radius while unrelated sentences scatter
/// (Manku et al. use exactly this feature class for web documents).
pub fn simhash_tokens(tokens: &[i32]) -> u64 {
    let mut votes = [0i32; 64];
    let mut any = false;
    let words = split_words(tokens);
    let feats: Vec<u64> = if words.len() >= 2 {
        words
            .windows(2)
            .map(|w| {
                let mut buf = Vec::with_capacity(16);
                for word in w {
                    for t in *word {
                        buf.push(*t as u8);
                    }
                    buf.push(0xFF); // word separator sentinel
                }
                xxh64(&buf, 0x51_4D_48_41) // "SMHA"
            })
            .collect()
    } else {
        words
            .iter()
            .map(|w| {
                let buf: Vec<u8> = w.iter().map(|&t| t as u8).collect();
                xxh64(&buf, 0x51_4D_48_41)
            })
            .collect()
    };
    for h in feats {
        any = true;
        for (b, vote) in votes.iter_mut().enumerate() {
            if (h >> b) & 1 == 1 {
                *vote += 1;
            } else {
                *vote -= 1;
            }
        }
    }
    if !any {
        return 0;
    }
    let mut out = 0u64;
    for (b, &vote) in votes.iter().enumerate() {
        if vote > 0 {
            out |= 1 << b;
        }
    }
    out
}

/// Split a byte-token stream into words at spaces / PAD.
fn split_words(tokens: &[i32]) -> Vec<&[i32]> {
    let mut out = Vec::new();
    let mut start = None;
    for (i, &t) in tokens.iter().enumerate() {
        let is_sep = t == 0 || t == b' ' as i32;
        match (start, is_sep) {
            (None, false) => start = Some(i),
            (Some(s), true) => {
                out.push(&tokens[s..i]);
                start = None;
            }
            _ => {}
        }
    }
    if let Some(s) = start {
        out.push(&tokens[s..]);
    }
    out
}

/// Hamming distance between two 64-bit signatures.
pub fn hamming(a: u64, b: u64) -> u32 {
    (a ^ b).count_ones()
}

/// Exact Jaccard similarity over *word-bigram* shingles — the
/// `Similarity(x,y)` verification step of Alg. A.6 (SimHash proposes,
/// Jaccard confirms).  Word bigrams match the SimHash feature class:
/// byte n-grams would rate same-template cross-user sentences as
/// near-duplicates (they share long literal runs), while word bigrams
/// put them at ~0.45 vs ≥0.6 for true paraphrases.
pub fn jaccard_shingles(a: &[i32], b: &[i32]) -> f64 {
    use std::collections::HashSet;
    let sh = |t: &[i32]| -> HashSet<Vec<i32>> {
        let words = split_words(t);
        if words.len() < 2 {
            return words.into_iter().map(|w| w.to_vec()).collect();
        }
        words
            .windows(2)
            .map(|w| {
                let mut v = w[0].to_vec();
                v.push(-1); // separator sentinel
                v.extend_from_slice(w[1]);
                v
            })
            .collect()
    };
    let sa = sh(a);
    let sb = sh(b);
    if sa.is_empty() && sb.is_empty() {
        return 1.0;
    }
    let inter = sa.intersection(&sb).count();
    let union = sa.len() + sb.len() - inter;
    inter as f64 / union as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::ByteTokenizer;

    fn toks(s: &str) -> Vec<i32> {
        ByteTokenizer.encode_fixed(s, 64)
    }

    #[test]
    fn identical_texts_identical_hash() {
        let a = simhash_tokens(&toks("Alice wrote about gardening on day 1."));
        let b = simhash_tokens(&toks("Alice wrote about gardening on day 1."));
        assert_eq!(a, b);
    }

    #[test]
    fn near_duplicates_are_close_unrelated_are_far() {
        let orig = toks("Alice (user 0001) wrote about gardening on day 042.");
        let near = toks("Alice (user 0001) wrote about gardening around day 042.");
        let far = toks("Completely different subject matter entirely, news at 9.");
        let h0 = simhash_tokens(&orig);
        let hn = simhash_tokens(&near);
        let hf = simhash_tokens(&far);
        // short documents have few word-bigram features, so each edit
        // flips several signature bits; what matters for the closure is
        // the margin between near-dups and strangers around tau_hamming
        // = 20 (ClosureParams::default).
        assert!(hamming(h0, hn) <= 20, "near dist {}", hamming(h0, hn));
        assert!(hamming(h0, hf) > 20, "far dist {}", hamming(h0, hf));
    }

    #[test]
    fn jaccard_orders_similarity() {
        let orig = toks("Alice (user 0001) wrote about gardening on day 042.");
        let near = toks("Alice (user 0001) wrote about gardening around day 042.");
        let far = toks("the secret code of user 0007 is 112233.");
        let jn = jaccard_shingles(&orig, &near);
        let jf = jaccard_shingles(&orig, &far);
        assert!(jn > 0.5, "jn={jn}");
        assert!(jf < 0.2, "jf={jf}");
        assert_eq!(jaccard_shingles(&orig, &orig), 1.0);
    }

    #[test]
    fn hamming_basics() {
        assert_eq!(hamming(0, 0), 0);
        assert_eq!(hamming(u64::MAX, 0), 64);
        assert_eq!(hamming(0b1010, 0b0110), 2);
    }

    #[test]
    fn short_and_empty_inputs() {
        assert_eq!(simhash_tokens(&[]), 0);
        let _ = simhash_tokens(&[1]);
        let _ = simhash_tokens(&[1, 2, 3]); // below shingle width
        assert_eq!(jaccard_shingles(&[1, 2], &[1, 2]), 1.0);
    }
}
