//! Banded Hamming index over 64-bit SimHash signatures.
//!
//! Standard Manku-style banding: split the signature into 4 bands of 16
//! bits; any two signatures within Hamming distance ≤ 3 collide in at
//! least one band (pigeonhole), so candidate retrieval is a 4-table
//! lookup + verify.  For radii > 3 we widen the search by probing
//! single-bit flips of each band (covers radius ≤ 7 with high recall at
//! toy corpus scale).  This plays the role FAISS ANN plays in the paper.

use std::collections::HashMap;

use super::simhash::hamming;

const BANDS: usize = 4;
const BAND_BITS: u32 = 16;

/// Multi-table banded index: signature -> doc ids.
#[derive(Debug, Default)]
pub struct HammingIndex {
    tables: [HashMap<u16, Vec<u64>>; BANDS],
    sigs: HashMap<u64, u64>, // id -> signature
}

fn band(sig: u64, b: usize) -> u16 {
    ((sig >> (b as u32 * BAND_BITS)) & 0xFFFF) as u16
}

impl HammingIndex {
    pub fn new() -> HammingIndex {
        HammingIndex::default()
    }

    pub fn len(&self) -> usize {
        self.sigs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sigs.is_empty()
    }

    pub fn insert(&mut self, id: u64, sig: u64) {
        self.sigs.insert(id, sig);
        for b in 0..BANDS {
            self.tables[b].entry(band(sig, b)).or_default().push(id);
        }
    }

    pub fn signature(&self, id: u64) -> Option<u64> {
        self.sigs.get(&id).copied()
    }

    /// IDs within Hamming distance `radius` of `sig` (verified exact).
    ///
    /// Exact for radius ≤ 3 (pigeonhole over 4 bands); single-bit band
    /// probing extends high-recall retrieval to radius ≤ 7.  Beyond that
    /// the banded tables cannot guarantee recall, so we fall back to a
    /// verified linear scan — at the paper's toy corpus scale (~2k docs)
    /// this is microseconds, and it preserves the *behaviour* of the
    /// paper's FAISS ANN search (see DESIGN.md substitutions).  Short
    /// documents make near-duplicate radii larger than web-scale SimHash
    /// (fewer features -> coarser votes), hence the wide default radius
    /// in `ClosureParams`.
    pub fn query(&self, sig: u64, radius: u32) -> Vec<u64> {
        if radius > 7 {
            return self.query_exact(sig, radius);
        }
        let mut cands: Vec<u64> = Vec::new();
        for b in 0..BANDS {
            let key = band(sig, b);
            if let Some(v) = self.tables[b].get(&key) {
                cands.extend_from_slice(v);
            }
            if radius > 3 {
                // probe single-bit perturbations of this band
                for bit in 0..BAND_BITS {
                    if let Some(v) = self.tables[b].get(&(key ^ (1 << bit))) {
                        cands.extend_from_slice(v);
                    }
                }
            }
        }
        cands.sort_unstable();
        cands.dedup();
        cands
            .into_iter()
            .filter(|id| hamming(self.sigs[id], sig) <= radius)
            .collect()
    }

    /// Brute-force query (ground truth for recall tests / benches).
    pub fn query_exact(&self, sig: u64, radius: u32) -> Vec<u64> {
        let mut out: Vec<u64> = self
            .sigs
            .iter()
            .filter(|(_, &s)| hamming(s, sig) <= radius)
            .map(|(&id, _)| id)
            .collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;
    use crate::util::rng::SplitMix64;

    #[test]
    fn exact_match_found() {
        let mut idx = HammingIndex::new();
        idx.insert(1, 0xDEAD_BEEF_0000_FFFF);
        idx.insert(2, 0x1234_5678_9ABC_DEF0);
        assert_eq!(idx.query(0xDEAD_BEEF_0000_FFFF, 0), vec![1]);
    }

    #[test]
    fn radius3_is_exact_vs_bruteforce() {
        let mut idx = HammingIndex::new();
        let mut rng = SplitMix64::new(4);
        let base = rng.next_u64();
        // plant signatures at controlled distances
        for d in 0..10u32 {
            let mut sig = base;
            for bit in 0..d {
                sig ^= 1 << (bit * 5);
            }
            idx.insert(d as u64, sig);
        }
        for radius in 0..=3 {
            assert_eq!(
                idx.query(base, radius),
                idx.query_exact(base, radius),
                "radius {radius}"
            );
        }
    }

    #[test]
    fn prop_banding_guarantee_radius3() {
        // any pair within distance 3 shares a band (pigeonhole over 4)
        for_all("banding pigeonhole", |rng| {
            let mut idx = HammingIndex::new();
            let sig = rng.next_u64();
            let mut other = sig;
            let flips = rng.below(4); // 0..=3 bit flips
            let mut flipped = std::collections::HashSet::new();
            for _ in 0..flips {
                let bit = rng.below(64) as u32;
                if flipped.insert(bit) {
                    other ^= 1 << bit;
                }
            }
            idx.insert(7, other);
            assert!(
                idx.query(sig, 3).contains(&7),
                "sig {sig:#x} other {other:#x}"
            );
        });
    }

    #[test]
    fn wide_radius_probing_recall() {
        let mut idx = HammingIndex::new();
        let mut rng = SplitMix64::new(9);
        let base = rng.next_u64();
        let mut expected = Vec::new();
        for i in 0..200u64 {
            let sig = if i < 20 {
                // within distance ≤ 6: flip up to 6 distinct bits
                let mut s = base;
                for b in 0..(i % 7) {
                    s ^= 1 << ((b * 9 + i) % 64);
                }
                if hamming(s, base) <= 6 {
                    expected.push(i);
                }
                s
            } else {
                rng.next_u64()
            };
            idx.insert(i, sig);
        }
        let got = idx.query(base, 6);
        let recall = expected.iter().filter(|e| got.contains(e)).count()
            as f64
            / expected.len().max(1) as f64;
        assert!(recall >= 0.9, "recall {recall}");
    }

    #[test]
    fn query_filters_false_band_collisions() {
        let mut idx = HammingIndex::new();
        // same low band, far overall
        idx.insert(1, 0x0000_0000_0000_1234);
        idx.insert(2, 0xFFFF_FFFF_FFFF_1234);
        let got = idx.query(0x0000_0000_0000_1234, 3);
        assert_eq!(got, vec![1]);
    }
}
