//! WAL integrity scan (Alg. 5.1 step 6 / Alg. A.8 step 6):
//! per-record CRC32, per-segment SHA-256 (+HMAC), `opt_step_u32` monotone
//! and gap-free, accumulation-boundary structure, no record gaps.

use std::path::Path;

use crate::util::hashing::{hex, hmac_sha256, sha256_hex};
use crate::util::json::parse;

use super::reader::WalReader;
use super::record::WalRecord;

/// Result of a WAL scan.  `ok()` is the CI-gate pass condition.
#[derive(Debug, Default)]
pub struct IntegrityReport {
    pub records: u64,
    pub segments: usize,
    pub crc_failures: Vec<u64>,
    pub checksum_failures: Vec<String>,
    pub step_order_violations: Vec<u64>,
    pub step_gaps: Vec<(u32, u32)>,
    pub boundary_violations: Vec<u64>,
    pub empty_microbatches: Vec<u64>,
}

impl IntegrityReport {
    pub fn ok(&self) -> bool {
        self.crc_failures.is_empty()
            && self.checksum_failures.is_empty()
            && self.step_order_violations.is_empty()
            && self.step_gaps.is_empty()
            && self.boundary_violations.is_empty()
            && self.empty_microbatches.is_empty()
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        let mut j = crate::util::json::Json::obj();
        j.set("ok", self.ok())
            .set("records", self.records)
            .set("segments", self.segments)
            .set("crc_failures", self.crc_failures.len())
            .set("checksum_failures", self.checksum_failures.len())
            .set("step_order_violations", self.step_order_violations.len())
            .set("step_gaps", self.step_gaps.len())
            .set("boundary_violations", self.boundary_violations.len())
            .set("empty_microbatches", self.empty_microbatches.len());
        j
    }
}

/// Full integrity scan of a WAL directory.
pub fn scan(dir: &Path, hmac_key: Option<&[u8]>) -> anyhow::Result<IntegrityReport> {
    let mut report = IntegrityReport::default();

    // 1. per-segment checksums
    let reader = WalReader::open(dir)?;
    report.segments = reader.segment_paths().len();
    for seg in reader.segment_paths() {
        let raw = std::fs::read(seg)?;
        let sum_path = seg.with_extension("seg.sum");
        if !sum_path.exists() {
            report
                .checksum_failures
                .push(format!("{}: missing .sum", seg.display()));
            continue;
        }
        let sum = parse(&std::fs::read_to_string(&sum_path)?)
            .map_err(|e| anyhow::anyhow!("bad sum json: {e}"))?;
        let expect_sha = sum
            .get("sha256")
            .and_then(|v| v.as_str())
            .unwrap_or_default()
            .to_string();
        if sha256_hex(&raw) != expect_sha {
            report
                .checksum_failures
                .push(format!("{}: sha256 mismatch", seg.display()));
        }
        if let (Some(key), Some(tag)) = (
            hmac_key,
            sum.get("hmac_sha256").and_then(|v| v.as_str()),
        ) {
            if hex(&hmac_sha256(key, &raw)) != tag {
                report
                    .checksum_failures
                    .push(format!("{}: hmac mismatch", seg.display()));
            }
        }
    }

    // 2. record stream: CRC (via decode), step monotonicity, gaps,
    //    accumulation structure
    let mut idx = 0u64;
    let mut last_step: Option<u32> = None;
    let mut last_was_end = true; // stream must start a fresh step
    for item in WalReader::open(dir)? {
        match item {
            Err(_) => report.crc_failures.push(idx),
            Ok(rec) => {
                check_record(&rec, idx, &mut last_step, &mut last_was_end,
                             &mut report);
            }
        }
        idx += 1;
    }
    if !last_was_end {
        // trailing unterminated accumulation segment
        report.boundary_violations.push(idx.saturating_sub(1));
    }
    report.records = idx;
    Ok(report)
}

fn check_record(
    rec: &WalRecord,
    idx: u64,
    last_step: &mut Option<u32>,
    last_was_end: &mut bool,
    report: &mut IntegrityReport,
) {
    if rec.mb_len == 0 {
        report.empty_microbatches.push(idx);
    }
    match *last_step {
        None => {}
        Some(prev) => {
            if *last_was_end {
                // a new logical step must be prev+1 (gap-free, monotone)
                if rec.opt_step < prev {
                    report.step_order_violations.push(idx);
                } else if rec.opt_step > prev + 1 {
                    report.step_gaps.push((prev, rec.opt_step));
                } else if rec.opt_step == prev {
                    // same step after its accum_end -> boundary violation
                    report.boundary_violations.push(idx);
                }
            } else if rec.opt_step != prev {
                // continuation microbatch must share the step counter
                report.step_order_violations.push(idx);
            }
        }
    }
    *last_step = Some(rec.opt_step);
    *last_was_end = rec.accum_end;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;
    use crate::wal::segment::WalWriter;

    fn rec(step: u32, end: bool) -> WalRecord {
        WalRecord {
            hash64: step as u64 * 31 + end as u64,
            seed64: 7,
            lr_bits: (1e-3f32).to_bits(),
            opt_step: step,
            accum_end: end,
            mb_len: 4,
        }
    }

    fn write_wal(dir: &std::path::Path, recs: &[WalRecord]) {
        let mut w = WalWriter::create(dir, 8, Some(b"key".to_vec())).unwrap();
        for r in recs {
            w.append(r).unwrap();
        }
        w.finish().unwrap();
    }

    #[test]
    fn clean_wal_passes() {
        let dir = tempdir("scan-clean");
        let recs: Vec<_> = (0..20u32)
            .flat_map(|t| vec![rec(t, false), rec(t, true)])
            .collect();
        write_wal(&dir, &recs);
        let rep = scan(&dir, Some(b"key")).unwrap();
        assert!(rep.ok(), "{rep:?}");
        assert_eq!(rep.records, 40);
    }

    #[test]
    fn detects_step_gap() {
        let dir = tempdir("scan-gap");
        write_wal(&dir, &[rec(0, true), rec(2, true)]);
        let rep = scan(&dir, None).unwrap();
        assert_eq!(rep.step_gaps, vec![(0, 2)]);
        assert!(!rep.ok());
    }

    #[test]
    fn detects_step_regression_and_boundary_violation() {
        let dir = tempdir("scan-order");
        write_wal(&dir, &[rec(3, true), rec(1, true)]);
        assert!(!scan(&dir, None).unwrap().ok());

        let dir2 = tempdir("scan-bound");
        // continuation record with a different step counter
        write_wal(&dir2, &[rec(0, false), rec(1, true)]);
        let rep = scan(&dir2, None).unwrap();
        assert!(!rep.step_order_violations.is_empty());
    }

    #[test]
    fn detects_unterminated_tail() {
        let dir = tempdir("scan-tail");
        write_wal(&dir, &[rec(0, true), rec(1, false)]);
        let rep = scan(&dir, None).unwrap();
        assert!(!rep.boundary_violations.is_empty());
    }

    #[test]
    fn detects_corrupted_record_and_checksum() {
        let dir = tempdir("scan-corrupt");
        write_wal(&dir, &[rec(0, true), rec(1, true), rec(2, true)]);
        let seg = dir.join("wal-000000.seg");
        let mut raw = std::fs::read(&seg).unwrap();
        raw[40] ^= 0xFF; // corrupt record 1 payload
        std::fs::write(&seg, raw).unwrap();
        let rep = scan(&dir, None).unwrap();
        assert_eq!(rep.crc_failures, vec![1]);
        assert!(!rep.checksum_failures.is_empty()); // segment sha now wrong
    }

    #[test]
    fn wrong_hmac_key_detected() {
        let dir = tempdir("scan-hmac");
        write_wal(&dir, &[rec(0, true)]);
        assert!(scan(&dir, Some(b"key")).unwrap().ok());
        let rep = scan(&dir, Some(b"WRONG")).unwrap();
        assert!(!rep.checksum_failures.is_empty());
    }
}
