//! The out-of-band manifest `M`: `hash64 → ordered sample IDs` (Def. 1).
//!
//! Access-controlled in production (it is the only artifact that links a
//! WAL record back to concrete samples).  Binary format, one entry per
//! microbatch: `[hash64 u64][count u16][id u64]*count`, with a trailing
//! file SHA-256 in a `.sum` sidecar.
//!
//! ## Retired IDs (laundered-set compaction)
//!
//! When a laundering pass retires a lineage, the laundered closure is
//! folded INTO the manifest as a **retired-ID set** (a `.retired`
//! sidecar): the per-entry ordered lists keep their bytes (the WAL
//! `hash64` and `mb_len` cross-checks stay intact), but every replay
//! traversal masks retired IDs automatically.  That is what lets the
//! lineage's `laundered.json` compact to an empty residue instead of
//! growing with service lifetime: the retired set is bounded by the
//! corpus (an ID retires at most once), not by how many laundering
//! passes ever ran.

use std::collections::{HashMap, HashSet};
use std::fs;
use std::path::Path;

use crate::util::hashing::{hash_ordered_ids, sha256_hex};

/// In-memory hash64 → ordered-IDs map.
#[derive(Debug, Default, Clone)]
pub struct IdMap {
    map: HashMap<u64, Vec<u64>>,
    /// Keyed (production) vs toy hashing — must match the trainer's mode.
    pub hmac_key: Option<Vec<u8>>,
    /// Sample IDs permanently masked out of every replay traversal —
    /// the compacted laundered closure (see module docs).  Monotone:
    /// IDs are only ever added, and at most once each.
    retired: HashSet<u64>,
}

impl IdMap {
    pub fn new(hmac_key: Option<Vec<u8>>) -> IdMap {
        IdMap {
            map: HashMap::new(),
            hmac_key,
            retired: HashSet::new(),
        }
    }

    /// Permanently mask `ids` out of every future replay traversal
    /// (idempotent — re-retiring is a no-op, so the set is bounded by
    /// the corpus regardless of how many laundering passes run).
    pub fn retire_ids<I: IntoIterator<Item = u64>>(&mut self, ids: I) {
        self.retired.extend(ids);
    }

    /// Whether `id` was laundered into the manifest's retired set.
    pub fn is_retired(&self, id: u64) -> bool {
        self.retired.contains(&id)
    }

    pub fn retired_len(&self) -> usize {
        self.retired.len()
    }

    /// Register a microbatch; returns its hash64 (what goes in the WAL).
    pub fn register(&mut self, ordered_ids: &[u64]) -> u64 {
        let h = hash_ordered_ids(ordered_ids, self.hmac_key.as_deref());
        self.map.insert(h, ordered_ids.to_vec());
        h
    }

    /// Look up the ordered IDs for a WAL record hash (Alg. A.9 line 5).
    pub fn lookup(&self, hash64: u64) -> Option<&[u64]> {
        self.map.get(&hash64).map(|v| v.as_slice())
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Verify an entry hashes to its key (tamper check used by the
    /// integrity scan).
    pub fn verify(&self, hash64: u64) -> bool {
        self.lookup(hash64)
            .map(|ids| hash_ordered_ids(ids, self.hmac_key.as_deref()) == hash64)
            .unwrap_or(false)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut buf = Vec::new();
        let mut keys: Vec<_> = self.map.keys().copied().collect();
        keys.sort_unstable(); // deterministic file image
        for h in keys {
            let ids = &self.map[&h];
            buf.extend_from_slice(&h.to_le_bytes());
            buf.extend_from_slice(&(ids.len() as u16).to_le_bytes());
            for id in ids {
                buf.extend_from_slice(&id.to_le_bytes());
            }
        }
        crate::util::faultfs::write(path, &buf)?;
        crate::util::faultfs::write(
            &path.with_extension("map.sum"),
            sha256_hex(&buf).as_bytes(),
        )?;
        // Retired-ID sidecar (laundered-set compaction).  Written even
        // when empty so a rewrite clears stale retirements; the entry
        // bytes above are untouched, preserving every hash64/mb_len
        // cross-check.  Size is a function of the DISTINCT retired set
        // (bounded by the corpus), never of how many laundering passes
        // wrote it.  The sidecar gets its own checksum (mirroring
        // `.map.sum`): after compaction it is the SOLE record masking
        // erased data out of replays, so corruption must fail closed —
        // and the harness cross-checks its cardinality against the
        // lineage's retired count at reopen, so silent LOSS of the pair
        // fails closed too.
        let mut retired: Vec<u64> = self.retired.iter().copied().collect();
        retired.sort_unstable();
        let sidecar = path.with_extension("map.retired");
        let encoded = crate::checkpoint::ids_json(&retired).encode();
        crate::checkpoint::write_atomic(&sidecar, &encoded)?;
        crate::util::faultfs::write(
            &sidecar.with_extension("retired.sum"),
            sha256_hex(encoded.as_bytes()).as_bytes(),
        )?;
        Ok(())
    }

    pub fn load(path: &Path, hmac_key: Option<Vec<u8>>) -> anyhow::Result<IdMap> {
        let buf = fs::read(path)?;
        let sum_path = path.with_extension("map.sum");
        if sum_path.exists() {
            let expect = fs::read_to_string(&sum_path)?;
            anyhow::ensure!(
                sha256_hex(&buf) == expect.trim(),
                "IdMap checksum mismatch for {}",
                path.display()
            );
        }
        let mut map = HashMap::new();
        let mut pos = 0usize;
        while pos < buf.len() {
            anyhow::ensure!(pos + 10 <= buf.len(), "truncated IdMap entry");
            let h = u64::from_le_bytes(buf[pos..pos + 8].try_into().unwrap());
            let n =
                u16::from_le_bytes(buf[pos + 8..pos + 10].try_into().unwrap())
                    as usize;
            pos += 10;
            anyhow::ensure!(pos + 8 * n <= buf.len(), "truncated IdMap ids");
            let ids = buf[pos..pos + 8 * n]
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            pos += 8 * n;
            map.insert(h, ids);
        }
        // retired sidecar: verify its checksum when one exists; a sum
        // without its sidecar means the retired set was lost — refuse
        // (post-compaction it is the only thing masking erased data)
        let sidecar = path.with_extension("map.retired");
        let sum_path = sidecar.with_extension("retired.sum");
        if sum_path.exists() {
            anyhow::ensure!(
                sidecar.exists(),
                "IdMap retired sidecar missing for {} (its checksum \
                 exists) — refusing: erased data would reenter replays",
                path.display()
            );
            let raw = fs::read(&sidecar)?;
            let expect = fs::read_to_string(&sum_path)?;
            anyhow::ensure!(
                sha256_hex(&raw) == expect.trim(),
                "IdMap retired-sidecar checksum mismatch for {}",
                path.display()
            );
        }
        let retired: HashSet<u64> =
            crate::checkpoint::read_ids_json(&sidecar)?
                .into_iter()
                .collect();
        Ok(IdMap {
            map,
            hmac_key,
            retired,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;
    use crate::util::tempdir;

    #[test]
    fn register_lookup_roundtrip() {
        let mut m = IdMap::new(None);
        let h = m.register(&[10, 20, 30]);
        assert_eq!(m.lookup(h).unwrap(), &[10, 20, 30]);
        assert!(m.verify(h));
        assert!(m.lookup(h ^ 1).is_none());
    }

    #[test]
    fn order_matters() {
        let mut m = IdMap::new(None);
        let a = m.register(&[1, 2, 3]);
        let b = m.register(&[3, 2, 1]);
        assert_ne!(a, b);
        assert_eq!(m.lookup(a).unwrap(), &[1, 2, 3]);
        assert_eq!(m.lookup(b).unwrap(), &[3, 2, 1]);
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tempdir("idmap");
        let mut m = IdMap::new(Some(b"k".to_vec()));
        let mut hashes = Vec::new();
        for i in 0..50u64 {
            hashes.push(m.register(&[i, i * 7, i * 13]));
        }
        let path = dir.join("ids.map");
        m.save(&path).unwrap();
        let back = IdMap::load(&path, Some(b"k".to_vec())).unwrap();
        for h in hashes {
            assert_eq!(back.lookup(h), m.lookup(h));
            assert!(back.verify(h));
        }
    }

    #[test]
    fn tamper_detected_on_load() {
        let dir = tempdir("idmap-tamper");
        let mut m = IdMap::new(None);
        m.register(&[1, 2, 3]);
        let path = dir.join("ids.map");
        m.save(&path).unwrap();
        let mut raw = std::fs::read(&path).unwrap();
        raw[12] ^= 0xFF;
        std::fs::write(&path, raw).unwrap();
        assert!(IdMap::load(&path, None).is_err());
    }

    #[test]
    fn retired_ids_roundtrip_and_stay_bounded() {
        // The laundered-set growth bound: the on-disk retired sidecar
        // (and the in-memory set) are a function of the DISTINCT retired
        // ids, not of how many laundering passes re-retired them — so
        // the file stops growing with service lifetime.
        let dir = tempdir("idmap-retired");
        let mut m = IdMap::new(None);
        let h = m.register(&[1, 2, 3, 4]);
        let path = dir.join("ids.map");
        m.retire_ids([2u64, 3]);
        assert!(m.is_retired(2) && m.is_retired(3));
        assert!(!m.is_retired(1));
        m.save(&path).unwrap();
        let sidecar = path.with_extension("map.retired");
        let size_once = std::fs::metadata(&sidecar).unwrap().len();
        // 100 more "laundering passes" retiring the same closure
        for _ in 0..100 {
            m.retire_ids([2u64, 3]);
            m.save(&path).unwrap();
        }
        assert_eq!(m.retired_len(), 2, "idempotent retirement");
        assert_eq!(
            std::fs::metadata(&sidecar).unwrap().len(),
            size_once,
            "sidecar bounded by the distinct retired set, not by passes"
        );
        // retirement survives a reload; entry bytes (hash cross-checks)
        // are untouched
        let back = IdMap::load(&path, None).unwrap();
        assert!(back.is_retired(2) && back.is_retired(3));
        assert!(!back.is_retired(1));
        assert_eq!(back.lookup(h).unwrap(), &[1, 2, 3, 4]);
        assert!(back.verify(h), "retirement never rewrites entry bytes");
    }

    #[test]
    fn maps_without_a_retired_sidecar_load_empty() {
        // pre-compaction ids.map files (no sidecar, no checksum) parse
        // as "nothing retired" — backwards compatible
        let dir = tempdir("idmap-no-sidecar");
        let mut m = IdMap::new(None);
        m.register(&[7, 8]);
        let path = dir.join("ids.map");
        m.save(&path).unwrap();
        let sidecar = path.with_extension("map.retired");
        std::fs::remove_file(&sidecar).unwrap();
        std::fs::remove_file(sidecar.with_extension("retired.sum")).unwrap();
        let back = IdMap::load(&path, None).unwrap();
        assert_eq!(back.retired_len(), 0);
    }

    #[test]
    fn retired_sidecar_corruption_or_loss_fails_closed() {
        // post-compaction the sidecar is the only record masking erased
        // data out of replays: tampering OR losing it (while its
        // checksum survives) must refuse the load, mirroring the main
        // file's .sum posture
        let dir = tempdir("idmap-retired-tamper");
        let mut m = IdMap::new(None);
        m.register(&[1, 2, 3]);
        m.retire_ids([2u64]);
        let path = dir.join("ids.map");
        m.save(&path).unwrap();
        let sidecar = path.with_extension("map.retired");
        // tamper: flip a byte in the retired set
        let raw = std::fs::read(&sidecar).unwrap();
        let mut bad = raw.clone();
        let i = bad.iter().position(|&b| b == b'2').unwrap();
        bad[i] = b'9';
        std::fs::write(&sidecar, &bad).unwrap();
        assert!(IdMap::load(&path, None).is_err(), "tamper fails closed");
        // loss: checksum present, sidecar gone
        std::fs::remove_file(&sidecar).unwrap();
        assert!(IdMap::load(&path, None).is_err(), "loss fails closed");
    }

    #[test]
    fn prop_roundtrip_random_maps() {
        let dir = tempdir("idmap-prop");
        let mut case = 0u64;
        for_all("idmap save/load", |rng| {
            case += 1;
            let mut m = IdMap::new(None);
            let k = rng.below(20) + 1;
            let mut hs = Vec::new();
            for _ in 0..k {
                let len = rng.below(16) as usize + 1;
                let ids: Vec<u64> =
                    (0..len).map(|_| rng.next_u64()).collect();
                hs.push((m.register(&ids), ids));
            }
            let p = dir.join(format!("m{case}.map"));
            m.save(&p).unwrap();
            let back = IdMap::load(&p, None).unwrap();
            for (h, ids) in hs {
                assert_eq!(back.lookup(h).unwrap(), ids.as_slice());
            }
        });
    }
}
