//! The 32-byte fixed-width WAL record (paper Def. 1).
//!
//! Wire layout (little-endian), 27-byte payload + CRC32 + 1 pad byte:
//!
//! | offset | field        | type | meaning                                   |
//! |--------|--------------|------|-------------------------------------------|
//! | 0      | hash64       | u64  | content hash of the *ordered* sample IDs  |
//! | 8      | seed64       | u64  | per-microbatch RNG seed bundle            |
//! | 16     | lr_f32       | f32  | exact LR value in effect                  |
//! | 20     | opt_step_u32 | u32  | logical optimizer-step counter            |
//! | 24     | accum_end_u8 | u8   | 1 = gradient-accumulation boundary        |
//! | 25     | mb_len_u16   | u16  | microbatch length (true, pre-padding)     |
//! | 27     | crc32        | u32  | CRC32 of bytes [0,27)                     |
//! | 31     | pad          | u8   | zero (32-byte alignment)                  |
//!
//! The paper's toy-only `sched_digest_u32` sidecar field is NOT part of
//! this binary record (it was a legacy human-readable log field, ignored
//! at replay); we reproduce that by emitting it only in the optional
//! debug sidecar (see [`super::segment::WalWriter::enable_sidecar`]).

use crate::util::hashing::crc32;

/// Fixed record size on the wire.
pub const RECORD_SIZE: usize = 32;
/// Payload bytes covered by the CRC.
pub const PAYLOAD_SIZE: usize = 27;

/// One per-microbatch WAL record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalRecord {
    /// 64-bit content hash over the ordered sample IDs (keyed HMAC in
    /// production mode; see `util::hashing::hash_ordered_ids`).
    pub hash64: u64,
    /// Per-microbatch RNG seed bundle consumed at replay.
    pub seed64: u64,
    /// Exact learning-rate value in effect at the accumulation boundary
    /// (stored as raw bits so the f32 value round-trips exactly).
    pub lr_bits: u32,
    /// Logical optimizer-step counter (authoritative during replay).
    pub opt_step: u32,
    /// True at gradient-accumulation boundaries.
    pub accum_end: bool,
    /// True microbatch length (samples before padding).
    pub mb_len: u16,
}

impl WalRecord {
    pub fn lr(&self) -> f32 {
        f32::from_bits(self.lr_bits)
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.lr_bits = lr.to_bits();
        self
    }

    /// Serialize to the 32-byte wire format (computes CRC).
    pub fn encode(&self) -> [u8; RECORD_SIZE] {
        let mut buf = [0u8; RECORD_SIZE];
        buf[0..8].copy_from_slice(&self.hash64.to_le_bytes());
        buf[8..16].copy_from_slice(&self.seed64.to_le_bytes());
        buf[16..20].copy_from_slice(&self.lr_bits.to_le_bytes());
        buf[20..24].copy_from_slice(&self.opt_step.to_le_bytes());
        buf[24] = self.accum_end as u8;
        buf[25..27].copy_from_slice(&self.mb_len.to_le_bytes());
        let crc = crc32(&buf[..PAYLOAD_SIZE]);
        buf[27..31].copy_from_slice(&crc.to_le_bytes());
        buf[31] = 0;
        buf
    }

    /// Parse + CRC-verify a 32-byte record.
    pub fn decode(buf: &[u8]) -> anyhow::Result<WalRecord> {
        anyhow::ensure!(
            buf.len() == RECORD_SIZE,
            "record must be {RECORD_SIZE} B, got {}",
            buf.len()
        );
        let stored_crc = u32::from_le_bytes(buf[27..31].try_into().unwrap());
        let actual_crc = crc32(&buf[..PAYLOAD_SIZE]);
        anyhow::ensure!(
            stored_crc == actual_crc,
            "WAL record CRC mismatch: stored {stored_crc:#x} != {actual_crc:#x}"
        );
        let accum = buf[24];
        anyhow::ensure!(accum <= 1, "invalid accum_end byte {accum}");
        Ok(WalRecord {
            hash64: u64::from_le_bytes(buf[0..8].try_into().unwrap()),
            seed64: u64::from_le_bytes(buf[8..16].try_into().unwrap()),
            lr_bits: u32::from_le_bytes(buf[16..20].try_into().unwrap()),
            opt_step: u32::from_le_bytes(buf[20..24].try_into().unwrap()),
            accum_end: accum == 1,
            mb_len: u16::from_le_bytes(buf[25..27].try_into().unwrap()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    fn sample() -> WalRecord {
        WalRecord {
            hash64: 0xDEAD_BEEF_CAFE_F00D,
            seed64: 42,
            lr_bits: 1e-3_f32.to_bits(),
            opt_step: 17,
            accum_end: true,
            mb_len: 8,
        }
    }

    #[test]
    fn encode_is_32_bytes() {
        assert_eq!(sample().encode().len(), 32);
        assert_eq!(RECORD_SIZE, 32); // the Table 7 constant
    }

    #[test]
    fn roundtrip() {
        let r = sample();
        assert_eq!(WalRecord::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn lr_roundtrips_exact_bits() {
        // the WAL stores the *exact* LR value (Lemma A.4) — raw bits
        for lr in [1e-3f32, 2.5e-4, f32::MIN_POSITIVE, 0.0] {
            let r = sample().with_lr(lr);
            let back = WalRecord::decode(&r.encode()).unwrap();
            assert_eq!(back.lr().to_bits(), lr.to_bits());
        }
    }

    #[test]
    fn crc_detects_corruption() {
        let mut buf = sample().encode();
        for i in 0..PAYLOAD_SIZE {
            buf[i] ^= 0x40;
            assert!(WalRecord::decode(&buf).is_err(), "flip at byte {i}");
            buf[i] ^= 0x40;
        }
        assert!(WalRecord::decode(&buf).is_ok());
    }

    #[test]
    fn prop_roundtrip_random_records() {
        for_all("wal record roundtrip", |rng| {
            let r = WalRecord {
                hash64: rng.next_u64(),
                seed64: rng.next_u64(),
                lr_bits: rng.next_u64() as u32,
                opt_step: rng.next_u64() as u32,
                accum_end: rng.below(2) == 1,
                mb_len: rng.below(65536) as u16,
            };
            assert_eq!(WalRecord::decode(&r.encode()).unwrap(), r);
        });
    }
}
