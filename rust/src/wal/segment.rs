//! WAL segment writer (paper Alg. A.1).
//!
//! Records append to rotating `wal-NNNNNN.seg` files.  Each segment gets
//! a SHA-256 checksum (and, in production mode, an HMAC-SHA256 tag)
//! written to `wal-NNNNNN.seg.sum` on rotation/close — the per-segment
//! integrity hash reported in the equality-proof artifact (Table 5).
//! `fsync` on rotation mirrors the paper's durability note.

use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::hashing::{hex, hmac_sha256, StreamingSha256};
use crate::util::json::Json;

use super::record::WalRecord;

/// Append-only WAL writer with segment rotation.
pub struct WalWriter {
    dir: PathBuf,
    records_per_segment: usize,
    hmac_key: Option<Vec<u8>>,
    seg_index: u64,
    seg_file: Option<File>,
    seg_hasher: StreamingSha256,
    seg_bytes: Vec<u8>, // retained for HMAC (segments are small: 32 B/rec)
    records_in_seg: usize,
    total_records: u64,
    sidecar: Option<File>,
}

impl WalWriter {
    /// Create a writer in `dir` (created if missing).  `hmac_key` enables
    /// production-mode per-segment HMAC tags.
    pub fn create(
        dir: &Path,
        records_per_segment: usize,
        hmac_key: Option<Vec<u8>>,
    ) -> anyhow::Result<WalWriter> {
        anyhow::ensure!(records_per_segment > 0, "segment size must be > 0");
        fs::create_dir_all(dir)?;
        let mut w = WalWriter {
            dir: dir.to_path_buf(),
            records_per_segment,
            hmac_key,
            seg_index: 0,
            seg_file: None,
            seg_hasher: StreamingSha256::new(),
            seg_bytes: Vec::new(),
            records_in_seg: 0,
            total_records: 0,
            sidecar: None,
        };
        w.open_segment()?;
        Ok(w)
    }

    /// Continue an existing WAL by opening a FRESH segment after the
    /// highest sealed one (online ingest: the trained tail advances in
    /// increments, each appending new segments).  Existing segments are
    /// never reopened — a torn increment is recovered by deleting whole
    /// uncommitted segments, which only works if increment boundaries
    /// coincide with segment boundaries.  `create_new` below still
    /// fail-closes if an uncommitted segment was left behind (recovery
    /// must run first).
    pub fn append_to(
        dir: &Path,
        records_per_segment: usize,
        hmac_key: Option<Vec<u8>>,
    ) -> anyhow::Result<WalWriter> {
        anyhow::ensure!(records_per_segment > 0, "segment size must be > 0");
        fs::create_dir_all(dir)?;
        let mut w = WalWriter {
            dir: dir.to_path_buf(),
            records_per_segment,
            hmac_key,
            seg_index: segment_count(dir)?,
            seg_file: None,
            seg_hasher: StreamingSha256::new(),
            seg_bytes: Vec::new(),
            records_in_seg: 0,
            total_records: 0,
            sidecar: None,
        };
        w.open_segment()?;
        Ok(w)
    }

    /// Enable the human-readable debug sidecar (CSV).  This is where the
    /// paper's toy-only legacy `sched_digest_u32` field lives; it is
    /// NEVER read at replay.
    pub fn enable_sidecar(&mut self) -> anyhow::Result<()> {
        // detlint: allow(raw-fs) — debug-only CSV, never read at replay or
        // recovery; crash-matrix coverage of it would prove nothing
        let mut f = File::create(self.dir.join("wal-sidecar.csv"))?;
        writeln!(
            f,
            "hash64,seed64,lr,opt_step,accum_end,mb_len,sched_digest_u32"
        )?;
        self.sidecar = Some(f);
        Ok(())
    }

    fn seg_path(&self, idx: u64) -> PathBuf {
        self.dir.join(format!("wal-{idx:06}.seg"))
    }

    fn open_segment(&mut self) -> anyhow::Result<()> {
        let path = self.seg_path(self.seg_index);
        let f = OpenOptions::new()
            .create_new(true)
            .write(true)
            .open(&path)?;
        self.seg_file = Some(f);
        self.seg_hasher = StreamingSha256::new();
        self.seg_bytes.clear();
        self.records_in_seg = 0;
        Ok(())
    }

    fn seal_segment(&mut self) -> anyhow::Result<()> {
        let Some(f) = self.seg_file.take() else {
            return Ok(());
        };
        f.sync_all()?; // fsync on rotation (Alg. A.1 step 5)
        let sha = std::mem::take(&mut self.seg_hasher).finalize_hex();
        let mut sum = Json::obj();
        sum.set("segment", self.seg_index)
            .set("records", self.records_in_seg)
            .set("sha256", sha.as_str());
        if let Some(key) = &self.hmac_key {
            sum.set("hmac_sha256", hex(&hmac_sha256(key, &self.seg_bytes)));
        }
        crate::util::faultfs::write(
            &self.seg_path(self.seg_index).with_extension("seg.sum"),
            sum.pretty().as_bytes(),
        )?;
        Ok(())
    }

    /// Append one record (Alg. A.1: atomic aligned append + CRC).
    pub fn append(&mut self, rec: &WalRecord) -> anyhow::Result<()> {
        if self.records_in_seg >= self.records_per_segment {
            self.seal_segment()?;
            self.seg_index += 1;
            self.open_segment()?;
        }
        let buf = rec.encode();
        self.seg_file
            .as_mut()
            .expect("segment open")
            .write_all(&buf)?;
        self.seg_hasher.update(&buf);
        self.seg_bytes.extend_from_slice(&buf);
        self.records_in_seg += 1;
        self.total_records += 1;
        if let Some(sc) = &mut self.sidecar {
            // legacy toy-only sched digest: CRC of (step, lr bits); ignored
            // at replay by construction (it is not in the binary record).
            let sched_digest = crate::util::hashing::crc32(
                &[rec.opt_step.to_le_bytes(), rec.lr_bits.to_le_bytes()]
                    .concat(),
            );
            writeln!(
                sc,
                "{:016x},{},{},{},{},{},{}",
                rec.hash64,
                rec.seed64,
                rec.lr(),
                rec.opt_step,
                rec.accum_end as u8,
                rec.mb_len,
                sched_digest
            )?;
        }
        Ok(())
    }

    pub fn total_records(&self) -> u64 {
        self.total_records
    }

    /// Total bytes appended so far (the Table 7 "WAL footprint").
    pub fn total_bytes(&self) -> u64 {
        self.total_records * super::record::RECORD_SIZE as u64
    }

    /// Seal the trailing segment and flush checksums.
    pub fn finish(mut self) -> anyhow::Result<()> {
        self.seal_segment()
    }
}

impl Drop for WalWriter {
    fn drop(&mut self) {
        let _ = self.seal_segment();
    }
}

/// Number of `wal-NNNNNN.seg` files in `dir` (0 if the dir is absent).
/// Segment indices are dense by construction, so this is also the next
/// free index — `append_to` and ingest recovery both key off it.  Names
/// that do not parse as `wal-<u64>.seg` are ignored (e.g. the sidecar).
pub fn segment_count(dir: &Path) -> anyhow::Result<u64> {
    if !dir.exists() {
        return Ok(0);
    }
    let mut next = 0u64;
    for entry in fs::read_dir(dir)? {
        let name = entry?.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(idx) = name
            .strip_prefix("wal-")
            .and_then(|s| s.strip_suffix(".seg"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        next = next.max(idx + 1);
    }
    Ok(next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wal::reader::WalReader;

    fn rec(step: u32, i: u64, end: bool) -> WalRecord {
        WalRecord {
            hash64: 0x1000 + i,
            seed64: 0x2000 + i,
            lr_bits: (1e-3f32).to_bits(),
            opt_step: step,
            accum_end: end,
            mb_len: 8,
        }
    }

    #[test]
    fn write_rotate_read_back() {
        let dir = crate::util::tempdir("wal-rotate");
        let mut w = WalWriter::create(&dir, 10, None).unwrap();
        let mut expect = Vec::new();
        for t in 0..25u32 {
            let r = rec(t, t as u64, true);
            w.append(&r).unwrap();
            expect.push(r);
        }
        assert_eq!(w.total_bytes(), 25 * 32);
        w.finish().unwrap();
        // 25 records, 10/segment -> 3 segments
        assert!(dir.join("wal-000002.seg").exists());
        let got: Vec<_> = WalReader::open(&dir)
            .unwrap()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn segment_checksums_written_and_valid() {
        let dir = crate::util::tempdir("wal-sums");
        let mut w = WalWriter::create(&dir, 4, Some(b"test-key".to_vec()))
            .unwrap();
        for t in 0..9u32 {
            w.append(&rec(t, t as u64, true)).unwrap();
        }
        w.finish().unwrap();
        for idx in 0..3 {
            let sum = std::fs::read_to_string(
                dir.join(format!("wal-{idx:06}.seg.sum")),
            )
            .unwrap();
            let j = crate::util::json::parse(&sum).unwrap();
            let sha = j.get("sha256").unwrap().as_str().unwrap().to_string();
            let raw = std::fs::read(dir.join(format!("wal-{idx:06}.seg")))
                .unwrap();
            assert_eq!(crate::util::hashing::sha256_hex(&raw), sha);
            assert!(j.get("hmac_sha256").is_some());
        }
    }

    #[test]
    fn append_to_continues_past_sealed_segments() {
        let dir = crate::util::tempdir("wal-append-to");
        let mut w = WalWriter::create(&dir, 4, None).unwrap();
        let mut expect = Vec::new();
        for t in 0..6u32 {
            let r = rec(t, t as u64, true);
            w.append(&r).unwrap();
            expect.push(r);
        }
        w.finish().unwrap(); // segments 0 (full) and 1 (partial)
        assert_eq!(segment_count(&dir).unwrap(), 2);
        let mut w = WalWriter::append_to(&dir, 4, None).unwrap();
        for t in 6..11u32 {
            let r = rec(t, t as u64, true);
            w.append(&r).unwrap();
            expect.push(r);
        }
        w.finish().unwrap(); // segments 2 and 3
        assert_eq!(segment_count(&dir).unwrap(), 4);
        let got: Vec<_> = WalReader::open(&dir)
            .unwrap()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn sidecar_has_legacy_sched_digest_but_binary_does_not() {
        let dir = crate::util::tempdir("wal-sidecar");
        let mut w = WalWriter::create(&dir, 100, None).unwrap();
        w.enable_sidecar().unwrap();
        w.append(&rec(0, 0, true)).unwrap();
        w.finish().unwrap();
        let sidecar =
            std::fs::read_to_string(dir.join("wal-sidecar.csv")).unwrap();
        assert!(sidecar.contains("sched_digest_u32"));
        let seg = std::fs::read(dir.join("wal-000000.seg")).unwrap();
        assert_eq!(seg.len(), 32); // exactly one 32 B record, no extras
    }

}
