//! Sequential WAL reader: iterates records across segment files in order.

use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use super::record::{WalRecord, RECORD_SIZE};

/// Iterator over every record in a WAL directory, in append order.
pub struct WalReader {
    segments: Vec<PathBuf>,
    seg_idx: usize,
    buf: Vec<u8>,
    pos: usize,
}

impl WalReader {
    pub fn open(dir: &Path) -> anyhow::Result<WalReader> {
        let mut segments: Vec<PathBuf> = fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().map(|e| e == "seg").unwrap_or(false)
            })
            .collect();
        segments.sort();
        Ok(WalReader {
            segments,
            seg_idx: 0,
            buf: Vec::new(),
            pos: 0,
        })
    }

    /// Paths of the segment files, in order.
    pub fn segment_paths(&self) -> &[PathBuf] {
        &self.segments
    }

    fn load_next_segment(&mut self) -> anyhow::Result<bool> {
        if self.seg_idx >= self.segments.len() {
            return Ok(false);
        }
        let path = &self.segments[self.seg_idx];
        self.seg_idx += 1;
        let mut f = fs::File::open(path)?;
        self.buf.clear();
        f.read_to_end(&mut self.buf)?;
        anyhow::ensure!(
            self.buf.len() % RECORD_SIZE == 0,
            "segment {} length {} not a multiple of {RECORD_SIZE}",
            path.display(),
            self.buf.len()
        );
        self.pos = 0;
        Ok(true)
    }

    /// Read all records eagerly (convenience for replay, which needs the
    /// whole tail anyway).
    pub fn read_all(self) -> anyhow::Result<Vec<WalRecord>> {
        self.collect()
    }
}

impl Iterator for WalReader {
    type Item = anyhow::Result<WalRecord>;

    fn next(&mut self) -> Option<Self::Item> {
        loop {
            if self.pos + RECORD_SIZE <= self.buf.len() {
                let rec =
                    WalRecord::decode(&self.buf[self.pos..self.pos + RECORD_SIZE]);
                self.pos += RECORD_SIZE;
                return Some(rec);
            }
            match self.load_next_segment() {
                Ok(true) => continue,
                Ok(false) => return None,
                Err(e) => return Some(Err(e)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir;
    use crate::wal::segment::WalWriter;

    #[test]
    fn empty_dir_yields_nothing() {
        let dir = tempdir("wal-empty");
        assert_eq!(WalReader::open(&dir).unwrap().count(), 0);
    }

    #[test]
    fn detects_truncated_segment() {
        let dir = tempdir("wal-trunc");
        let mut w = WalWriter::create(&dir, 100, None).unwrap();
        w.append(&WalRecord {
            hash64: 1,
            seed64: 2,
            lr_bits: 0,
            opt_step: 0,
            accum_end: true,
            mb_len: 1,
        })
        .unwrap();
        w.finish().unwrap();
        // truncate mid-record
        let seg = dir.join("wal-000000.seg");
        let data = std::fs::read(&seg).unwrap();
        std::fs::write(&seg, &data[..17]).unwrap();
        let mut rd = WalReader::open(&dir).unwrap();
        assert!(rd.next().unwrap().is_err());
    }
}
