//! Microbatch write-ahead log (paper Def. 1, §4.1, Alg. A.1).
//!
//! For every microbatch the trainer emits one fixed-width 32-byte record
//! `⟨hash64, seed64, lr_f32, opt_step_u32, accum_end_u8, mb_len_u16,
//! crc32⟩` — no raw text, gradients or activations.  Records append to
//! rotating segment files with a per-segment SHA-256 (and optional HMAC),
//! mirroring ARIES-style minimal redo logging.
//!
//! The out-of-band ID map (`hash64 → ordered sample IDs`) lives in
//! [`idmap`]; it is the access-controlled manifest `M` of Def. 1.

pub mod idmap;
pub mod integrity;
pub mod reader;
pub mod record;
pub mod segment;

pub use idmap::IdMap;
pub use integrity::{scan, IntegrityReport};
pub use reader::WalReader;
pub use record::{WalRecord, RECORD_SIZE};
pub use segment::{segment_count, WalWriter};
