//! Synthetic user-document corpus (the paper's toy workload, §6: 2,009
//! samples, forget = 45) with the ingredients the audits need:
//!
//! - per-user documents (PII-flavoured templated text) so forget requests
//!   can be user-scoped,
//! - **canaries** in the Carlini secret-sharer style ("the secret code of
//!   user NNN is DDDDDD") inserted into the forget users' documents,
//! - **near-duplicates / paraphrases** of a fraction of documents, so the
//!   closure expansion (Alg. A.6) has real work to do,
//! - optional **cohort tags** for the adapter path (G2).

use crate::util::rng::SplitMix64;

use super::tokenizer::ByteTokenizer;

/// What a sample is, for audit bookkeeping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SampleKind {
    Normal,
    /// Canary with its embedded secret digits.
    Canary { secret: String },
    /// Near-duplicate of another sample id.
    NearDup { of: u64 },
}

/// One training sample.
#[derive(Debug, Clone)]
pub struct Sample {
    /// Stable sample ID (what the WAL hash64 covers, via the IdMap).
    pub id: u64,
    /// Owning user (forget requests arrive per user).
    pub user: u32,
    /// Cohort tag for adapter-scoped training (None = base corpus).
    pub cohort: Option<u32>,
    pub kind: SampleKind,
    pub text: String,
    /// Fixed-length token window (seq_len).
    pub tokens: Vec<i32>,
}

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_users: usize,
    pub docs_per_user: usize,
    /// Users whose docs carry canaries (these become the forget users).
    pub n_canary_users: usize,
    pub canaries_per_user: usize,
    /// Probability that a doc gets a near-duplicate emitted.
    pub near_dup_rate: f64,
    pub seq_len: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        // ≈ the paper's toy scale: ~2000 samples
        CorpusConfig {
            n_users: 200,
            docs_per_user: 9,
            n_canary_users: 5,
            canaries_per_user: 3,
            near_dup_rate: 0.05,
            seq_len: 64,
            seed: 20260710,
        }
    }
}

/// Generated corpus.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub samples: Vec<Sample>,
    pub config: CorpusConfig,
}

const FIRST: &[&str] = &[
    "Alice", "Bob", "Carol", "Dmitri", "Elena", "Farid", "Grace", "Hiro",
    "Ines", "Jonas", "Katya", "Liam", "Mona", "Nadia", "Omar", "Priya",
];
const TOPICS: &[&str] = &[
    "gardening", "astronomy", "cycling", "pottery", "chess", "surfing",
    "baking", "birdwatching", "climbing", "photography",
];
/// Equal-entropy user tag: zero-padded decimal ids would make low-
/// numbered users' text intrinsically easier to model (repeated bytes),
/// confounding leakage audits with content entropy.
pub fn user_tag(user: u32) -> String {
    format!("{:04x}", crate::util::rng::philox_u64(0x7A6, user as u64) & 0xFFFF)
}

const VERBS: &[&str] = &[
    "wrote about", "asked about", "complained about", "praised",
    "reviewed", "researched", "summarized", "discussed",
];

impl Corpus {
    /// Deterministic generation from the config seed.
    pub fn generate(config: CorpusConfig) -> Corpus {
        let tok = ByteTokenizer;
        let mut rng = SplitMix64::new(config.seed);
        let mut samples = Vec::new();
        let mut next_id = 0u64;
        let mut dup_backlog: Vec<(u64, u32, String)> = Vec::new();

        for user in 0..config.n_users as u32 {
            let name = FIRST[rng.below(FIRST.len() as u64) as usize];
            let is_canary_user = (user as usize) < config.n_canary_users;
            for d in 0..config.docs_per_user {
                let is_canary =
                    is_canary_user && d < config.canaries_per_user;
                let (text, kind) = if is_canary {
                    let secret = format!("{:06}", rng.below(1_000_000));
                    (
                        format!(
                            "the secret code of user {} is {secret}.",
                            user_tag(user)
                        ),
                        SampleKind::Canary { secret },
                    )
                } else {
                    let topic =
                        TOPICS[rng.below(TOPICS.len() as u64) as usize];
                    let verb =
                        VERBS[rng.below(VERBS.len() as u64) as usize];
                    (
                        format!(
                            "{name} (user {}) {verb} {topic} on day {:03}.",
                            user_tag(user),
                            rng.below(365)
                        ),
                        SampleKind::Normal,
                    )
                };
                let id = next_id;
                next_id += 1;
                if !is_canary && rng.f64() < config.near_dup_rate {
                    dup_backlog.push((id, user, text.clone()));
                }
                samples.push(Sample {
                    id,
                    user,
                    cohort: None,
                    kind,
                    tokens: tok.encode_fixed(&text, config.seq_len),
                    text,
                });
            }
        }

        // emit near-duplicates (light paraphrase perturbations)
        for (of, user, text) in dup_backlog {
            let variant = match rng.below(3) {
                0 => text.replace(" on day ", " around day "),
                1 => format!("{} indeed.", text.trim_end_matches('.')),
                _ => text.replace("(user", "( user"),
            };
            let id = next_id;
            next_id += 1;
            samples.push(Sample {
                id,
                user,
                cohort: None,
                kind: SampleKind::NearDup { of },
                tokens: tok.encode_fixed(&variant, config.seq_len),
                text: variant,
            });
        }

        Corpus { samples, config }
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn by_id(&self, id: u64) -> Option<&Sample> {
        // ids are dense and index-aligned by construction
        self.samples.get(id as usize).filter(|s| s.id == id)
    }

    /// All sample IDs belonging to a user (how forget requests arrive).
    pub fn user_samples(&self, user: u32) -> Vec<u64> {
        self.samples
            .iter()
            .filter(|s| s.user == user)
            .map(|s| s.id)
            .collect()
    }

    /// All canary samples (for exposure audits).
    pub fn canaries(&self) -> Vec<&Sample> {
        self.samples
            .iter()
            .filter(|s| matches!(s.kind, SampleKind::Canary { .. }))
            .collect()
    }

    /// Assign a cohort tag to every sample of the given users (adapter
    /// path workloads).
    pub fn tag_cohort(&mut self, users: &[u32], cohort: u32) {
        for s in &mut self.samples {
            if users.contains(&s.user) {
                s.cohort = Some(cohort);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = Corpus::generate(CorpusConfig::default());
        let b = Corpus::generate(CorpusConfig::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn scale_matches_paper_toy_run() {
        let c = Corpus::generate(CorpusConfig::default());
        // ~2000 samples like the paper's 2,009 (dup count is stochastic)
        assert!(c.len() >= 1800 && c.len() <= 2200, "got {}", c.len());
        assert_eq!(c.canaries().len(), 15);
    }

    #[test]
    fn ids_are_dense_and_lookup_works() {
        let c = Corpus::generate(CorpusConfig::default());
        for (i, s) in c.samples.iter().enumerate() {
            assert_eq!(s.id, i as u64);
        }
        assert_eq!(c.by_id(5).unwrap().id, 5);
        assert!(c.by_id(10_000_000).is_none());
    }

    #[test]
    fn canaries_carry_their_secret() {
        let c = Corpus::generate(CorpusConfig::default());
        for s in c.canaries() {
            if let SampleKind::Canary { secret } = &s.kind {
                assert!(s.text.contains(secret.as_str()));
                assert_eq!(secret.len(), 6);
            }
        }
    }

    #[test]
    fn near_dups_reference_existing_samples_and_differ_slightly() {
        let c = Corpus::generate(CorpusConfig::default());
        let dups: Vec<_> = c
            .samples
            .iter()
            .filter_map(|s| match s.kind {
                SampleKind::NearDup { of } => Some((s, of)),
                _ => None,
            })
            .collect();
        assert!(!dups.is_empty());
        for (dup, of) in dups {
            let orig = c.by_id(of).unwrap();
            assert_ne!(dup.text, orig.text);
            // still substantially similar (shares > half its words)
            let ow: std::collections::HashSet<_> =
                orig.text.split_whitespace().collect();
            let shared = dup
                .text
                .split_whitespace()
                .filter(|w| ow.contains(w))
                .count();
            assert!(shared * 2 >= ow.len(), "{} vs {}", dup.text, orig.text);
        }
    }

    #[test]
    fn user_scoping_and_cohorts() {
        let mut c = Corpus::generate(CorpusConfig::default());
        let u0 = c.user_samples(0);
        assert!(u0.len() >= CorpusConfig::default().docs_per_user);
        c.tag_cohort(&[3, 4], 7);
        assert!(c
            .samples
            .iter()
            .all(|s| (s.cohort == Some(7)) == (s.user == 3 || s.user == 4)));
    }
}
