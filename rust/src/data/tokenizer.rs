//! Byte-level tokenizer with a pinned checksum (paper §5: "fixed
//! tokenizer build (checksum pinned)").
//!
//! The spec string is shared verbatim with `python/compile/config.py`
//! (TOKENIZER_SPEC); its SHA-256 is one of the Table 2 reproducibility
//! pins and replay refuses to run if it drifts.

use crate::util::hashing::sha256_hex;

/// Must match `python/compile/config.py::TOKENIZER_SPEC` byte-for-byte.
pub const TOKENIZER_SPEC: &str = "byte-tokenizer-v1:vocab=256,pad=0,newline-doc-sep";

/// Vocabulary size (all byte values).
pub const VOCAB: usize = 256;
/// Padding token id.
pub const PAD: i32 = 0;

/// Byte-level tokenizer: token id == byte value.
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// The pinned checksum recorded in the AOT manifest and the forget
    /// manifest (Table 2).
    pub fn checksum() -> String {
        sha256_hex(TOKENIZER_SPEC.as_bytes())
    }

    /// Encode text; truncate or right-pad with [`PAD`] to `len` tokens.
    pub fn encode_fixed(&self, text: &str, len: usize) -> Vec<i32> {
        let mut out: Vec<i32> = text
            .bytes()
            .take(len)
            .map(|b| b as i32)
            .collect();
        out.resize(len, PAD);
        out
    }

    /// Encode without padding.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    /// Decode (lossy on invalid UTF-8; PAD bytes are dropped).
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t != PAD)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksum_is_stable_pin() {
        // changing the spec string is a breaking change: this vector is
        // the pin that both sides (aot manifest / rust config) must agree
        // on, so we lock it here.
        assert_eq!(ByteTokenizer::checksum().len(), 64);
        assert_eq!(ByteTokenizer::checksum(), ByteTokenizer::checksum());
    }

    #[test]
    fn encode_fixed_pads_and_truncates() {
        let t = ByteTokenizer;
        let e = t.encode_fixed("hi", 5);
        assert_eq!(e, vec![104, 105, 0, 0, 0]);
        let e = t.encode_fixed("hello world", 5);
        assert_eq!(e.len(), 5);
        assert_eq!(t.decode(&e), "hello");
    }

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let s = "User 0042's secret code is 918273.";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn vocab_covers_all_bytes() {
        let t = ByteTokenizer;
        let all: Vec<u8> = (1..=255u8).collect(); // 0 is PAD
        let s = String::from_utf8_lossy(&all).into_owned();
        let enc = t.encode(&s);
        assert!(enc.iter().all(|&x| (0..VOCAB as i32).contains(&x)));
    }
}
