//! Data pipeline: checksum-pinned byte tokenizer, synthetic user corpus
//! with canaries and near-duplicates, and the deterministic sampler
//! (fixed global order, explicit accumulation boundaries — paper §5).

pub mod corpus;
pub mod sampler;
pub mod tokenizer;

pub use corpus::{Corpus, CorpusConfig, Sample, SampleKind};
pub use sampler::{DeterministicSampler, Microbatch};
pub use tokenizer::ByteTokenizer;
