//! Deterministic sampler (paper §5 data pipeline + Lemma A.15).
//!
//! Produces a *global ordered list of example IDs per epoch* (seeded
//! shuffle), slices it into fixed-size microbatches with explicit
//! gradient-accumulation boundaries, and never repacks: the logical
//! microbatch graph G is a pure function of (corpus size, seed, batch,
//! accum, steps), which is exactly the "preserved graph" precondition
//! the replay proof needs.

use crate::util::rng::{microbatch_seed, SplitMix64};

/// One microbatch of the logical graph G.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Microbatch {
    /// Logical optimizer step (0-based).
    pub step: u32,
    /// Index within the accumulation segment.
    pub mb_index: u32,
    /// Ordered sample IDs (true length; padding happens at tensor build).
    pub sample_ids: Vec<u64>,
    /// True iff this is the last microbatch of its logical step.
    pub accum_end: bool,
    /// Per-microbatch RNG seed bundle (the WAL seed64 field).
    pub seed64: u64,
}

/// Fixed-order sampler over a corpus of `n_samples` dense IDs.
#[derive(Debug, Clone)]
pub struct DeterministicSampler {
    pub n_samples: usize,
    pub batch: usize,
    pub accum: usize,
    pub steps: u32,
    pub run_seed: u64,
}

impl DeterministicSampler {
    pub fn new(
        n_samples: usize,
        batch: usize,
        accum: usize,
        steps: u32,
        run_seed: u64,
    ) -> DeterministicSampler {
        assert!(n_samples > 0 && batch > 0 && accum > 0 && steps > 0);
        DeterministicSampler {
            n_samples,
            batch,
            accum,
            steps,
            run_seed,
        }
    }

    /// The global ordered ID list for an epoch (seeded Fisher-Yates).
    pub fn epoch_order(&self, epoch: u32) -> Vec<u64> {
        let mut ids: Vec<u64> = (0..self.n_samples as u64).collect();
        let mut rng =
            SplitMix64::new(self.run_seed ^ (0xE90C_u64 << 32) ^ epoch as u64);
        rng.shuffle(&mut ids);
        ids
    }

    /// Number of microbatches per logical step.
    pub fn microbatches_per_step(&self) -> usize {
        self.accum
    }

    /// Materialize the full logical microbatch graph G for the run.
    /// Samples cycle through epochs as needed; microbatch composition
    /// never depends on membership (Lemma A.15's hypothesis).
    pub fn schedule(&self) -> Vec<Microbatch> {
        let mut out = Vec::new();
        let mut epoch = 0u32;
        let mut order = self.epoch_order(epoch);
        let mut cursor = 0usize;
        for step in 0..self.steps {
            for i in 0..self.accum {
                let mut ids = Vec::with_capacity(self.batch);
                for _ in 0..self.batch {
                    if cursor >= order.len() {
                        epoch += 1;
                        order = self.epoch_order(epoch);
                        cursor = 0;
                    }
                    ids.push(order[cursor]);
                    cursor += 1;
                }
                out.push(Microbatch {
                    step,
                    mb_index: i as u32,
                    sample_ids: ids,
                    accum_end: i == self.accum - 1,
                    seed64: microbatch_seed(self.run_seed, step, i as u32),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::for_all;

    #[test]
    fn schedule_is_deterministic() {
        let s = DeterministicSampler::new(100, 8, 2, 10, 42);
        assert_eq!(s.schedule(), s.schedule());
    }

    #[test]
    fn different_seed_different_order() {
        let a = DeterministicSampler::new(100, 8, 2, 10, 1).schedule();
        let b = DeterministicSampler::new(100, 8, 2, 10, 2).schedule();
        assert_ne!(a[0].sample_ids, b[0].sample_ids);
    }

    #[test]
    fn accumulation_boundaries_are_explicit() {
        let s = DeterministicSampler::new(1000, 4, 3, 5, 7);
        let sched = s.schedule();
        assert_eq!(sched.len(), 15);
        for mb in &sched {
            assert_eq!(mb.accum_end, mb.mb_index == 2);
            assert_eq!(mb.sample_ids.len(), 4);
        }
        // steps are contiguous and ordered
        let steps: Vec<u32> = sched.iter().map(|m| m.step).collect();
        assert_eq!(steps, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3, 4, 4, 4]);
    }

    #[test]
    fn epoch_covers_every_sample_once() {
        let s = DeterministicSampler::new(64, 8, 1, 8, 5);
        let sched = s.schedule();
        let mut seen: Vec<u64> =
            sched.iter().flat_map(|m| m.sample_ids.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..64u64).collect::<Vec<_>>());
    }

    #[test]
    fn epoch_wraparound_reshuffles() {
        let s = DeterministicSampler::new(16, 8, 1, 4, 9);
        let sched = s.schedule();
        let epoch0: Vec<u64> = sched[..2]
            .iter()
            .flat_map(|m| m.sample_ids.clone())
            .collect();
        let epoch1: Vec<u64> = sched[2..]
            .iter()
            .flat_map(|m| m.sample_ids.clone())
            .collect();
        let mut a = epoch0.clone();
        let mut b = epoch1.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b); // same coverage
        assert_ne!(epoch0, epoch1); // different order
    }

    #[test]
    fn seeds_are_unique_per_microbatch() {
        let s = DeterministicSampler::new(100, 2, 4, 25, 3);
        let sched = s.schedule();
        let mut seen = std::collections::HashSet::new();
        for mb in &sched {
            assert!(seen.insert(mb.seed64));
        }
    }

    #[test]
    fn prop_graph_shape_invariants() {
        for_all("sampler graph invariants", |rng| {
            let n = rng.below(500) as usize + 1;
            let batch = rng.below(8) as usize + 1;
            let accum = rng.below(4) as usize + 1;
            let steps = rng.below(20) as u32 + 1;
            let s = DeterministicSampler::new(n, batch, accum, steps,
                                              rng.next_u64());
            let sched = s.schedule();
            assert_eq!(sched.len(), steps as usize * accum);
            for (i, mb) in sched.iter().enumerate() {
                assert_eq!(mb.step as usize, i / accum);
                assert_eq!(mb.mb_index as usize, i % accum);
                assert_eq!(mb.sample_ids.len(), batch);
                assert_eq!(mb.accum_end, (i % accum) == accum - 1);
            }
        });
    }
}
