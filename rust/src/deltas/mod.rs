//! Dense per-step delta ring buffer — exact recent reverts (paper G3,
//! Alg. A.3, Tables 3 & 8).
//!
//! Two patch constructions, both proven exact in Theorem A.11:
//! - **XOR patches** over the raw f32 bit patterns: bitwise-exact revert
//!   (⊕ is its own inverse), including optimizer tensors when enabled.
//! - **Arithmetic deltas** `Δ_t = fl(θ_{t+1} − θ_t)`: numerically exact
//!   up to one rounding per step (O(u·ulp) backward error).
//!
//! Patches are losslessly compressed (byte-plane + sharded DEFLATE, see
//! `util::compress`) — compression never alters bit patterns.
//!
//! ## Hot-path architecture
//!
//! The seed built three full byte images per tensor per step (serialize
//! `after`, serialize `before`, transposed planes) before compressing.
//! `record` now runs the fused XOR+transpose
//! ([`crate::util::compress::plane_split_xor_into`]) over zero-copy
//! tensor views straight into one reused scratch buffer, then hands the
//! planes to the sharded scoped-thread DEFLATE — zero redundant images,
//! zero steady-state allocation.  `revert` fuses the inverse transpose
//! into the patch application
//! ([`crate::util::compress::plane_join_xor_in_place`] /
//! [`plane_join_sub_f32_in_place`]) so the state tensor is patched
//! through its own byte view, word-wise, in one pass.
//! [`RingBudget`] additionally reports measured wall-time per
//! `record`/`revert` step (the Table 8 latency columns).

use std::collections::VecDeque;
use std::time::Instant;

use crate::checkpoint::TrainState;
use crate::util::compress::{
    compress_planes, decompress_planes, plane_join_sub_f32_in_place,
    plane_join_xor_in_place, plane_split_into, plane_split_xor_into,
};
use crate::util::simd;

/// Patch construction mode (Alg. A.3 input).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PatchMode {
    /// Bitwise XOR over raw dtype bit patterns — revert is bit-exact.
    Xor,
    /// Arithmetic f32 deltas — revert exact up to rounding.
    Arithmetic,
}

/// One stored per-step patch (possibly covering optimizer tensors).
struct Patch {
    /// Logical step this patch transitions FROM->TO (t -> t+1).
    step: u32,
    params: Vec<u8>, // compressed planes
    m: Option<Vec<u8>>,
    v: Option<Vec<u8>>,
    raw_len: usize,
    compressed_len: usize,
}

/// Ring buffer of the last N per-step patches.
pub struct DeltaRing {
    pub mode: PatchMode,
    pub window: usize,
    pub revert_optimizer: bool,
    ring: VecDeque<Patch>,
    param_count: usize,
    /// Reused plane-transposed scratch (one tensor image, no per-step
    /// allocation in steady state).
    planes_scratch: Vec<u8>,
    /// Reused arithmetic-delta scratch (Arithmetic mode only).
    delta_scratch: Vec<f32>,
    records: u64,
    record_secs_total: f64,
    record_secs_last: f64,
    reverts: u64,
    revert_secs_total: f64,
}

/// Budget accounting for Table 8.
#[derive(Debug, Clone, PartialEq)]
pub struct RingBudget {
    pub per_step_bytes_raw: usize,
    pub window: usize,
    pub pre_compress_total: usize,
    pub stored_bytes: usize,
    pub compress_ratio: f64,
    /// `record` calls observed (lifetime, not just the current window).
    pub record_count: u64,
    /// Mean wall-time per `record` call (seconds).
    pub record_secs_mean: f64,
    /// Wall-time of the most recent `record` call (seconds).
    pub record_secs_last: f64,
    /// Mean wall-time per reverted step (seconds).
    pub revert_secs_mean: f64,
}

impl DeltaRing {
    pub fn new(
        param_count: usize,
        window: usize,
        mode: PatchMode,
        revert_optimizer: bool,
    ) -> DeltaRing {
        DeltaRing {
            mode,
            window: window.max(1),
            revert_optimizer,
            ring: VecDeque::new(),
            param_count,
            planes_scratch: Vec::new(),
            delta_scratch: Vec::new(),
            records: 0,
            record_secs_total: 0.0,
            record_secs_last: 0.0,
            reverts: 0,
            revert_secs_total: 0.0,
        }
    }

    /// Build one compressed patch for `before -> after` without
    /// materializing intermediate byte images (scratch is reused).
    fn make_patch(
        &mut self,
        before: &[f32],
        after: &[f32],
    ) -> anyhow::Result<Vec<u8>> {
        anyhow::ensure!(
            before.len() == after.len(),
            "patch tensor length mismatch: {} vs {}",
            before.len(),
            after.len()
        );
        self.planes_scratch.resize(after.len() * 4, 0);
        match self.mode {
            PatchMode::Xor => {
                plane_split_xor_into(
                    simd::as_bytes(after),
                    simd::as_bytes(before),
                    &mut self.planes_scratch,
                )?;
            }
            PatchMode::Arithmetic => {
                self.delta_scratch.clear();
                self.delta_scratch.extend(
                    after.iter().zip(before).map(|(a, b)| a - b), // fl(θ_{t+1} − θ_t)
                );
                plane_split_into(
                    simd::as_bytes(&self.delta_scratch),
                    &mut self.planes_scratch,
                )?;
            }
        }
        compress_planes(&self.planes_scratch)
    }

    /// Apply one stored patch onto `current` in place (fused
    /// un-transpose + XOR/subtract over the zero-copy byte view).
    fn apply_patch(&self, patch: &[u8], current: &mut [f32]) -> anyhow::Result<()> {
        let planes = decompress_planes(patch, current.len() * 4)?;
        match self.mode {
            PatchMode::Xor => {
                plane_join_xor_in_place(&planes, simd::as_bytes_mut(current))
            }
            PatchMode::Arithmetic => {
                plane_join_sub_f32_in_place(&planes, current)
            }
        }
    }

    /// Record the transition `before -> after` for step `before.logical_step`.
    pub fn record(
        &mut self,
        before: &TrainState,
        after: &TrainState,
    ) -> anyhow::Result<()> {
        self.record_parts(
            before.logical_step,
            &before.params,
            &before.m,
            &before.v,
            after,
        )
    }

    /// [`DeltaRing::record`] from borrowed tensor parts — lets the
    /// trainer hand over the pre-update tensors it just swapped out
    /// instead of cloning the full `TrainState` every step.
    pub fn record_parts(
        &mut self,
        step: u32,
        before_params: &[f32],
        before_m: &[f32],
        before_v: &[f32],
        after: &TrainState,
    ) -> anyhow::Result<()> {
        let t0 = Instant::now();
        anyhow::ensure!(
            before_params.len() == self.param_count
                && after.params.len() == self.param_count,
            "ring param count mismatch"
        );
        let params = self.make_patch(before_params, &after.params)?;
        let (m, v) = if self.revert_optimizer {
            (
                Some(self.make_patch(before_m, &after.m)?),
                Some(self.make_patch(before_v, &after.v)?),
            )
        } else {
            (None, None)
        };
        let compressed_len = params.len()
            + m.as_ref().map(|x| x.len()).unwrap_or(0)
            + v.as_ref().map(|x| x.len()).unwrap_or(0);
        let raw_len = self.param_count * 4 * if self.revert_optimizer { 3 } else { 1 };
        self.ring.push_back(Patch {
            step,
            params,
            m,
            v,
            raw_len,
            compressed_len,
        });
        while self.ring.len() > self.window {
            self.ring.pop_front();
        }
        let dt = t0.elapsed().as_secs_f64();
        self.records += 1;
        self.record_secs_total += dt;
        self.record_secs_last = dt;
        Ok(())
    }

    /// Reverts restore bits exactly: XOR patches covering the optimizer
    /// tensors (Thm. A.11(a)).  Arithmetic patches revert only up to
    /// rounding — the one predicate the planner, executor and batch
    /// coalescer all gate bit-identity guarantees on.
    pub fn bit_exact_reverts(&self) -> bool {
        self.mode == PatchMode::Xor && self.revert_optimizer
    }

    /// How many trailing steps can currently be reverted.
    pub fn available(&self) -> usize {
        self.ring.len()
    }

    /// Drop every stored patch.  Called on a checkpoint-lineage swap:
    /// a laundered base diverges from the logged trajectory the ring
    /// patches, so no stored transition can ever apply again — holding
    /// the patches would only pin memory and invite misuse.  Lifetime
    /// record/revert counters are preserved (they time future records).
    pub fn clear(&mut self) {
        self.ring.clear();
    }

    /// Earliest step still revertible (the ring's reach).
    pub fn earliest_step(&self) -> Option<u32> {
        self.ring.front().map(|p| p.step)
    }

    /// Latest recorded transition step.
    pub fn latest_step(&self) -> Option<u32> {
        self.ring.back().map(|p| p.step)
    }

    /// Revert the last `u` steps in place (Alg. A.3).  Patches are popped:
    /// after reverting, those steps are no longer in the ring (they no
    /// longer lie "in the past" of the current state).
    pub fn revert(&mut self, state: &mut TrainState, u: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            u <= self.ring.len(),
            "revert window exceeded: requested {u}, available {}",
            self.ring.len()
        );
        let t0 = Instant::now();
        for _ in 0..u {
            let patch = self.ring.pop_back().expect("checked length");
            self.apply_patch(&patch.params, &mut state.params)?;
            if self.revert_optimizer {
                if let (Some(pm), Some(pv)) = (&patch.m, &patch.v) {
                    self.apply_patch(pm, &mut state.m)?;
                    self.apply_patch(pv, &mut state.v)?;
                    state.applied_updates =
                        state.applied_updates.saturating_sub(1);
                }
            }
            state.logical_step = patch.step;
        }
        self.reverts += u as u64;
        self.revert_secs_total += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Compressed size of each stored patch, oldest → newest.  A revert
    /// of depth `u` decompresses the last `u` entries (planner cost
    /// input — summed per-candidate at plan time).
    pub fn patch_sizes(&self) -> Vec<usize> {
        self.ring.iter().map(|p| p.compressed_len).collect()
    }

    /// Table 8 accounting.
    pub fn budget(&self) -> RingBudget {
        let per_step_raw = self
            .ring
            .back()
            .map(|p| p.raw_len)
            .unwrap_or(self.param_count * 4);
        let stored: usize = self.ring.iter().map(|p| p.compressed_len).sum();
        let pre: usize = self.ring.iter().map(|p| p.raw_len).sum();
        RingBudget {
            per_step_bytes_raw: per_step_raw,
            window: self.window,
            pre_compress_total: pre,
            stored_bytes: stored,
            compress_ratio: if pre > 0 {
                stored as f64 / pre as f64
            } else {
                0.0
            },
            record_count: self.records,
            record_secs_mean: if self.records > 0 {
                self.record_secs_total / self.records as f64
            } else {
                0.0
            },
            record_secs_last: self.record_secs_last,
            revert_secs_mean: if self.reverts > 0 {
                self.revert_secs_total / self.reverts as f64
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::bits_equal;
    use crate::util::prop::{f32_vec, f32_vec_adversarial, for_all};
    use crate::util::rng::SplitMix64;

    fn walk(seed: u64, n: usize, steps: usize) -> Vec<TrainState> {
        let mut r = SplitMix64::new(seed);
        let mut s = TrainState::zeros_like(f32_vec(&mut r, n, 1.0));
        s.m = f32_vec(&mut r, n, 0.01);
        s.v = f32_vec(&mut r, n, 0.01)
            .into_iter()
            .map(f32::abs)
            .collect();
        let mut states = vec![s.clone()];
        for t in 0..steps {
            for i in 0..n {
                s.params[i] += r.normal() as f32 * 1e-3;
                s.m[i] = 0.9 * s.m[i] + r.normal() as f32 * 1e-4;
                s.v[i] = (0.999 * s.v[i] + 1e-6).abs();
            }
            s.applied_updates += 1;
            s.logical_step = t as u32 + 1;
            states.push(s.clone());
        }
        states
    }

    #[test]
    fn xor_revert_is_bitwise_exact() {
        let states = walk(1, 500, 10);
        let mut ring = DeltaRing::new(500, 16, PatchMode::Xor, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, 4).unwrap();
        assert!(cur.bits_equal(&states[states.len() - 5]), "G3(a)");
    }

    #[test]
    fn arithmetic_revert_is_close() {
        let states = walk(2, 500, 8);
        let mut ring = DeltaRing::new(500, 16, PatchMode::Arithmetic, false);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, 8).unwrap();
        let target = &states[0];
        let diff = crate::util::bytes::max_abs_diff(&cur.params, &target.params);
        // O(u·ulp) per Theorem A.11(b)
        assert!(diff <= 8.0 * f32::EPSILON * 4.0, "diff {diff}");
    }

    #[test]
    fn window_slides() {
        let states = walk(3, 100, 20);
        let mut ring = DeltaRing::new(100, 5, PatchMode::Xor, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        assert_eq!(ring.available(), 5);
        assert_eq!(ring.earliest_step(), Some(15));
        let mut cur = states.last().unwrap().clone();
        assert!(ring.revert(&mut cur, 6).is_err(), "beyond window");
        ring.revert(&mut cur, 5).unwrap();
        assert!(cur.bits_equal(&states[15]));
    }

    #[test]
    fn xor_exact_on_adversarial_bits() {
        for_all("xor revert nan/inf/denormal", |rng| {
            let n = rng.below(300) as usize + 1;
            let mut s0 = TrainState::zeros_like(f32_vec_adversarial(rng, n));
            s0.m = f32_vec_adversarial(rng, n);
            s0.v = f32_vec_adversarial(rng, n);
            let mut s1 = s0.clone();
            s1.params = f32_vec_adversarial(rng, n);
            s1.m = f32_vec_adversarial(rng, n);
            s1.v = f32_vec_adversarial(rng, n);
            s1.applied_updates = 1;
            s1.logical_step = 1;
            let mut ring = DeltaRing::new(n, 4, PatchMode::Xor, true);
            ring.record(&s0, &s1).unwrap();
            let mut cur = s1.clone();
            ring.revert(&mut cur, 1).unwrap();
            assert!(bits_equal(&cur.params, &s0.params));
            assert!(bits_equal(&cur.m, &s0.m));
            assert!(bits_equal(&cur.v, &s0.v));
        });
    }

    #[test]
    fn record_parts_equals_record_of_states() {
        let states = walk(8, 200, 3);
        let mut a = DeltaRing::new(200, 8, PatchMode::Xor, true);
        let mut b = DeltaRing::new(200, 8, PatchMode::Xor, true);
        for w in states.windows(2) {
            a.record(&w[0], &w[1]).unwrap();
            b.record_parts(
                w[0].logical_step,
                &w[0].params,
                &w[0].m,
                &w[0].v,
                &w[1],
            )
            .unwrap();
        }
        let mut ca = states.last().unwrap().clone();
        let mut cb = states.last().unwrap().clone();
        a.revert(&mut ca, 3).unwrap();
        b.revert(&mut cb, 3).unwrap();
        assert!(ca.bits_equal(&cb));
        assert!(ca.bits_equal(&states[0]));
    }

    #[test]
    fn budget_reports_table8_fields() {
        let states = walk(4, 1000, 16);
        let mut ring = DeltaRing::new(1000, 16, PatchMode::Xor, false);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let b = ring.budget();
        assert_eq!(b.window, 16);
        assert_eq!(b.per_step_bytes_raw, 4000);
        assert_eq!(b.pre_compress_total, 16 * 4000);
        assert!(b.compress_ratio > 0.0 && b.compress_ratio <= 1.2);
        // wall-time accounting (Table 8 latency columns)
        assert_eq!(b.record_count, 16);
        assert!(b.record_secs_mean > 0.0);
        assert!(b.record_secs_last > 0.0);
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, 2).unwrap();
        assert!(ring.budget().revert_secs_mean > 0.0);
    }

    #[test]
    fn mismatched_tensor_lengths_fail_closed() {
        let states = walk(6, 50, 1);
        let mut ring = DeltaRing::new(64, 4, PatchMode::Xor, false);
        // param_count 64 but tensors are 50-long
        assert!(ring.record(&states[0], &states[1]).is_err());
    }

    #[test]
    fn clear_invalidates_every_patch() {
        let states = walk(7, 80, 5);
        let mut ring = DeltaRing::new(80, 8, PatchMode::Xor, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        assert_eq!(ring.available(), 5);
        ring.clear();
        assert_eq!(ring.available(), 0);
        assert_eq!(ring.earliest_step(), None);
        let mut cur = states.last().unwrap().clone();
        assert!(ring.revert(&mut cur, 1).is_err(), "nothing to revert");
        assert!(cur.bits_equal(states.last().unwrap()));
        // budget survives (lifetime counters), stored bytes drop to zero
        let b = ring.budget();
        assert_eq!(b.record_count, 5);
        assert_eq!(b.stored_bytes, 0);
        // the ring records fresh transitions after a clear
        ring.record(&states[0], &states[1]).unwrap();
        assert_eq!(ring.available(), 1);
    }

    #[test]
    fn revert_pops_consumed_patches() {
        let states = walk(5, 50, 6);
        let mut ring = DeltaRing::new(50, 8, PatchMode::Xor, true);
        for w in states.windows(2) {
            ring.record(&w[0], &w[1]).unwrap();
        }
        let mut cur = states.last().unwrap().clone();
        ring.revert(&mut cur, 2).unwrap();
        assert_eq!(ring.available(), 4);
        ring.revert(&mut cur, 4).unwrap();
        assert!(cur.bits_equal(&states[0]));
        assert_eq!(ring.available(), 0);
    }
}
