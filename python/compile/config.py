"""Model configuration and flat parameter layout.

The entire parameter set is a single flat f32[P] vector.  The layout
(ordered list of named tensors with shapes and offsets) is computed here
and exported to ``artifacts/manifest.json`` so the Rust coordinator can
checkpoint / delta / hash parameters without knowing the model internals.

Layout order (stable; any change bumps ``LAYOUT_VERSION``):
  embed(V,D), pos(S,D),
  per layer l: ln1_scale(D), ln1_bias(D), w_qkv(D,3D), w_out(D,D),
               ln2_scale(D), ln2_bias(D), w_mlp_in(D,F), b_mlp_in(F),
               w_mlp_out(F,D), b_mlp_out(D),
  lnf_scale(D), lnf_bias(D)

LoRA layout order (rank r adapters on w_qkv and w_mlp_in):
  per layer l: A_qkv(r,D), B_qkv(3D,r), A_mlp(r,D), B_mlp(F,r)
"""

from dataclasses import dataclass, field, asdict
import math

LAYOUT_VERSION = 1

# Byte-level tokenizer contract shared with the Rust side (data/tokenizer.rs).
# sha256 of this exact string is the "tokenizer checksum" pin of Table 2.
TOKENIZER_SPEC = "byte-tokenizer-v1:vocab=256,pad=0,newline-doc-sep"


@dataclass
class ModelConfig:
    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 256
    seq_len: int = 64
    batch: int = 8           # train microbatch size (baked into HLO)
    eval_batch: int = 16     # eval batch size (baked into HLO)
    dropout: float = 0.0     # baked at trace time; seed is still an input
    lora_rank: int = 4
    init_seed: int = 1234
    # AdamW hyperparameters (baked into the update artifact)
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def layout(self):
        """Ordered [(name, shape)] of the flat parameter vector."""
        V, D, S, F, L = self.vocab, self.d_model, self.seq_len, self.d_ff, self.n_layers
        out = [("embed", (V, D)), ("pos", (S, D))]
        for l in range(L):
            out += [
                (f"l{l}.ln1_scale", (D,)),
                (f"l{l}.ln1_bias", (D,)),
                (f"l{l}.w_qkv", (D, 3 * D)),
                (f"l{l}.w_out", (D, D)),
                (f"l{l}.ln2_scale", (D,)),
                (f"l{l}.ln2_bias", (D,)),
                (f"l{l}.w_mlp_in", (D, F)),
                (f"l{l}.b_mlp_in", (F,)),
                (f"l{l}.w_mlp_out", (F, D)),
                (f"l{l}.b_mlp_out", (D,)),
            ]
        out += [("lnf_scale", (D,)), ("lnf_bias", (D,))]
        return out

    def lora_layout(self):
        D, F, L, r = self.d_model, self.d_ff, self.n_layers, self.lora_rank
        out = []
        for l in range(L):
            out += [
                (f"l{l}.A_qkv", (r, D)),
                (f"l{l}.B_qkv", (3 * D, r)),
                (f"l{l}.A_mlp", (r, D)),
                (f"l{l}.B_mlp", (F, r)),
            ]
        return out

    @property
    def param_count(self) -> int:
        return sum(math.prod(s) for _, s in self.layout())

    @property
    def lora_param_count(self) -> int:
        return sum(math.prod(s) for _, s in self.lora_layout())

    def offsets(self, layout):
        """[(name, shape, offset)] with running offsets."""
        off, out = 0, []
        for name, shape in layout:
            out.append((name, shape, off))
            off += math.prod(shape)
        return out

    def to_dict(self):
        d = asdict(self)
        d["param_count"] = self.param_count
        d["lora_param_count"] = self.lora_param_count
        d["layout_version"] = LAYOUT_VERSION
        d["tokenizer_spec"] = TOKENIZER_SPEC
        d["layout"] = [
            {"name": n, "shape": list(s), "offset": o}
            for n, s, o in self.offsets(self.layout())
        ]
        d["lora_layout"] = [
            {"name": n, "shape": list(s), "offset": o}
            for n, s, o in self.offsets(self.lora_layout())
        ]
        return d


def tiny() -> ModelConfig:
    """Default toy config (~0.12M params) used by tests and quickstart."""
    return ModelConfig()


def small() -> ModelConfig:
    """~1M params config used by the end-to-end example."""
    return ModelConfig(d_model=128, n_heads=4, n_layers=4, d_ff=512, seq_len=64)
