"""L1 Pallas kernel: fused AdamW elementwise update.

This is the ``Update`` pure function of the paper's Eq. (2)/(4): the
replay-exactness argument (Assumption A.13) requires Update to be a pure,
deterministic function of (params, grad, moments, step, lr) — fusing the
whole elementwise chain into one kernel keeps it a single pass over the
parameter vector (one HBM read/write per tensor on real hardware; tiles
sized in 8x128 multiples stream through VMEM).

Scalars (lr, bias corrections, clip scale, hyperparameters) ride in a
small f32[8] vector broadcast to every tile.  Global-norm clipping is
computed by the caller (it is a reduction, not elementwise) and passed in
as ``clip_scale``.

Runs under ``interpret=True`` on this image; see attention.py note.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 4096  # 8*512 elements per program instance; f32 tile = 16 KiB VMEM


def _adamw_kernel(sc_ref, p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref):
    """sc_ref: f32[8] = [lr, beta1, beta2, eps, wd, bc1, bc2, clip_scale]."""
    lr, b1, b2, eps = sc_ref[0], sc_ref[1], sc_ref[2], sc_ref[3]
    wd, bc1, bc2, cs = sc_ref[4], sc_ref[5], sc_ref[6], sc_ref[7]
    g = g_ref[...] * cs
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    p = p_ref[...]
    po_ref[...] = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + wd * p)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


def adamw_fused(p, g, m, v, scalars, tile: int = TILE):
    """Apply the fused AdamW kernel over flat f32[P] vectors.

    ``scalars`` = f32[8] [lr, beta1, beta2, eps, weight_decay, bc1, bc2,
    clip_scale].  P is padded up to a tile multiple internally.
    """
    n = p.shape[0]
    n_pad = (n + tile - 1) // tile * tile
    pad = n_pad - n

    def padded(x):
        return jnp.pad(x, (0, pad)) if pad else x

    p_, g_, m_, v_ = padded(p), padded(g), padded(m), padded(v)
    out_shape = [jax.ShapeDtypeStruct((n_pad,), jnp.float32)] * 3
    po, mo, vo = pl.pallas_call(
        _adamw_kernel,
        grid=(n_pad // tile,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),  # scalars broadcast to tiles
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_shape=out_shape,
        interpret=True,  # mandatory on CPU PJRT
    )(scalars, p_, g_, m_, v_)
    if pad:
        po, mo, vo = po[:n], mo[:n], vo[:n]
    return po, mo, vo


def adamw_update(p, g, m, v, step, lr, *, beta1, beta2, eps, weight_decay,
                 clip_norm, use_pallas=True):
    """Full Update: global-norm clip (c=clip_norm) then fused AdamW.

    ``step``: i32 scalar, 1-based applied-update counter (paper's
    opt_step semantics — bias correction sees only applied updates).
    """
    gnorm = jnp.sqrt(jnp.sum(g * g))
    clip_scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    stepf = step.astype(jnp.float32)
    bc1 = 1.0 - jnp.power(beta1, stepf)
    bc2 = 1.0 - jnp.power(beta2, stepf)
    if use_pallas:
        scalars = jnp.stack([
            jnp.asarray(lr, jnp.float32).reshape(()),
            jnp.float32(beta1), jnp.float32(beta2), jnp.float32(eps),
            jnp.float32(weight_decay), bc1, bc2, clip_scale,
        ])
        return adamw_fused(p, g, m, v, scalars)
    from . import ref
    return ref.adamw_ref(p, g * clip_scale, m, v, stepf, lr, beta1, beta2,
                         eps, weight_decay, jnp.float32(1.0))
