"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: ``pytest python/tests`` asserts the
Pallas kernels (run under ``interpret=True``) match these within float32
tolerance, with hypothesis sweeping shapes.  The L2 model can also be
built entirely from these functions (``model.build_fns(use_pallas=False)``)
which is how we cross-check the full lowered graph.
"""

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """Multi-head scaled-dot-product attention.

    q, k, v: f32[B, H, S, Dh].  Returns f32[B, H, S, Dh].
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)


def adamw_ref(p, g, m, v, step, lr, beta1, beta2, eps, weight_decay, clip_scale):
    """AdamW elementwise update (after global-norm clip by ``clip_scale``).

    All arrays f32[P]; ``step`` is the 1-based applied-update index used for
    bias correction; returns (p', m', v').
    """
    g = g * clip_scale
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    bc1 = 1.0 - beta1 ** step
    bc2 = 1.0 - beta2 ** step
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    p_new = p - lr * (m_hat / (jnp.sqrt(v_hat) + eps) + weight_decay * p)
    return p_new, m_new, v_new


def softmax_xent_ref(logits, targets):
    """Per-position cross entropy; logits f32[..., V], int targets[...]."""
    m = jnp.max(logits, axis=-1)
    logz = jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1)) + m
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - gold
