"""L1 Pallas kernel: causal flash attention (tiled online softmax).

TPU-style structure (see DESIGN.md §Hardware-Adaptation): the S×S score
matrix is never materialized; Q is tiled into ``block_q`` rows held in
VMEM, K/V stream through in ``block_k`` chunks, and the two matmuls
(QK^T, PV) target the MXU.  On this image the kernel must run with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls), so the
kernel is validated for *structure and numerics*, not wallclock.

The backward pass is a custom VJP that recomputes attention with the
pure-jnp reference math — the standard pragmatic pairing for Pallas
kernels whose fwd is the hot path.  Gradients are therefore exact w.r.t.
the reference semantics; pytest cross-checks both passes against
``ref.attention_ref``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, block_k, seq_len, causal):
    """One (batch*head, q-block) program instance.

    q_ref: f32[block_q, Dh]   (VMEM tile of queries)
    k_ref: f32[S, Dh]         (keys, streamed in block_k chunks below)
    v_ref: f32[S, Dh]
    o_ref: f32[block_q, Dh]
    """
    block_q, d_head = q_ref.shape
    q_blk = pl.program_id(1)
    # accumulate in f32 regardless of input dtype (MXU-style f32 acc)
    q = q_ref[...].astype(jnp.float32) * scale

    num_k_blocks = seq_len // block_k

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # [block_q, block_k] — MXU matmul
        if causal:
            q_ids = q_blk * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            )
            k_ids = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(q_ids >= k_ids, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v  # MXU matmul
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d_head), dtype=jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, num_k_blocks, body, (acc0, m0, l0))
    o_ref[...] = acc / l_i[:, None]


def _flash_fwd_impl(q, k, v, *, block_q, block_k, causal):
    b, h, s, dh = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = 1.0 / (dh ** 0.5)
    qf = q.reshape(b * h, s, dh)
    kf = k.reshape(b * h, s, dh)
    vf = v.reshape(b * h, s, dh)
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_k=block_k, seq_len=s, causal=causal
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, s, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, s, dh), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dh), jnp.float32),
        interpret=True,  # mandatory on CPU PJRT (no Mosaic)
    )(qf, kf, vf)
    return out.reshape(b, h, s, dh)


def _attn_bwd_math(q, k, v, g, causal):
    """Reference attention backward (recompute); used by the custom VJP."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, g)
    dp = jnp.einsum("bhqd,bhkd->bhqk", g, v)
    ds = p * (dp - jnp.sum(p * dp, axis=-1, keepdims=True))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q) * scale
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, block_q=16, block_k=16, causal=True):
    """Causal flash attention, f32[B,H,S,Dh] -> f32[B,H,S,Dh]."""
    return _flash_fwd_impl(q, k, v, block_q=block_q, block_k=block_k, causal=causal)


def _fwd(q, k, v, block_q, block_k, causal):
    o = _flash_fwd_impl(q, k, v, block_q=block_q, block_k=block_k, causal=causal)
    return o, (q, k, v)


def _bwd(block_q, block_k, causal, res, g):
    q, k, v = res
    return _attn_bwd_math(q, k, v, g, causal)


flash_attention.defvjp(_fwd, _bwd)
