"""L2: the training-program compute graphs, written in JAX.

Every graph here is lowered ONCE by ``aot.py`` to HLO text and executed
from the Rust coordinator via PJRT; Python never runs on the request
path.  Parameters are a single flat f32[P] vector (layout in config.py).

The key exactness property (paper Lemma A.2(ii) + Prop. A.8) lives here:
``train_step`` takes a per-example ``mask`` and computes the loss with
reduction=sum, so filtered examples contribute *exactly zero* addends
while tensor shapes, kernel launch orders and RNG draws stay identical.
This is what makes ReplayFilter and the preserved-graph oracle retrain
bit-identical when they run the same compiled executable.
"""

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ref
from .kernels.attention import flash_attention
from .kernels.adamw import adamw_update


# ---------------------------------------------------------------------------
# parameter (un)flattening
# ---------------------------------------------------------------------------

def unflatten(cfg: ModelConfig, flat):
    """Flat f32[P] -> dict of named tensors per cfg.layout()."""
    out = {}
    for name, shape, off in cfg.offsets(cfg.layout()):
        n = math.prod(shape)
        out[name] = flat[off:off + n].reshape(shape)
    return out


def unflatten_lora(cfg: ModelConfig, flat):
    out = {}
    for name, shape, off in cfg.offsets(cfg.lora_layout()):
        n = math.prod(shape)
        out[name] = flat[off:off + n].reshape(shape)
    return out


def init_params(cfg: ModelConfig):
    """Deterministic initialization (seeded); exported as init_params.bin."""
    key = jax.random.key(cfg.init_seed)
    chunks = []
    for name, shape in cfg.layout():
        key, sub = jax.random.split(key)
        n = math.prod(shape)
        if "ln" in name and "scale" in name:
            chunks.append(jnp.ones(n, jnp.float32))
        elif "bias" in name or name.endswith(("b_mlp_in", "b_mlp_out")):
            chunks.append(jnp.zeros(n, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 0.02 if name in ("embed", "pos") else 1.0 / math.sqrt(fan_in)
            chunks.append(jax.random.normal(sub, (n,), jnp.float32) * std)
    return jnp.concatenate(chunks)


def init_lora(cfg: ModelConfig):
    """A ~ small normal, B = 0 (standard LoRA init: patch starts at zero)."""
    key = jax.random.key(cfg.init_seed + 77)
    chunks = []
    for name, shape in cfg.lora_layout():
        key, sub = jax.random.split(key)
        n = math.prod(shape)
        if name.split(".")[-1].startswith("A"):
            chunks.append(jax.random.normal(sub, (n,), jnp.float32) * 0.01)
        else:
            chunks.append(jnp.zeros(n, jnp.float32))
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _dropout(x, rate, key):
    if rate <= 0.0:
        return x
    keep = jax.random.bernoulli(key, 1.0 - rate, x.shape)
    return jnp.where(keep, x / (1.0 - rate), 0.0)


def forward(cfg: ModelConfig, params_flat, tokens, seed=None, *,
            dropout=0.0, use_pallas=True, lora_flat=None):
    """Logits for a token batch.

    tokens: i32[B, S].  Returns f32[B, S, V].  ``seed`` (i32 scalar) feeds
    counter-based dropout streams — draws depend only on (seed, position),
    never on batch *content*, which is the index-stability requirement of
    Lemma A.2.  ``lora_flat`` optionally applies additive low-rank patches
    (W + (B@A)^T) on w_qkv / w_mlp_in with the base strictly frozen by the
    caller (G2).
    """
    p = unflatten(cfg, params_flat)
    lora = unflatten_lora(cfg, lora_flat) if lora_flat is not None else None
    B, S = tokens.shape
    D, H, Dh = cfg.d_model, cfg.n_heads, cfg.d_head

    x = p["embed"][tokens] + p["pos"][None, :S, :]
    if dropout > 0.0:
        base_key = jax.random.key(seed.astype(jnp.uint32))
    for l in range(cfg.n_layers):
        h = _layer_norm(x, p[f"l{l}.ln1_scale"], p[f"l{l}.ln1_bias"])
        w_qkv = p[f"l{l}.w_qkv"]
        if lora is not None:
            w_qkv = w_qkv + (lora[f"l{l}.B_qkv"] @ lora[f"l{l}.A_qkv"]).T
        qkv = h @ w_qkv  # [B,S,3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(B, S, H, Dh).transpose(0, 2, 1, 3)

        if use_pallas:
            att = flash_attention(heads(q), heads(k), heads(v))
        else:
            att = ref.attention_ref(heads(q), heads(k), heads(v))
        att = att.transpose(0, 2, 1, 3).reshape(B, S, D)
        att = att @ p[f"l{l}.w_out"]
        if dropout > 0.0:
            att = _dropout(att, dropout, jax.random.fold_in(base_key, 2 * l))
        x = x + att

        h = _layer_norm(x, p[f"l{l}.ln2_scale"], p[f"l{l}.ln2_bias"])
        w_in = p[f"l{l}.w_mlp_in"]
        if lora is not None:
            w_in = w_in + (lora[f"l{l}.B_mlp"] @ lora[f"l{l}.A_mlp"]).T
        ff = jax.nn.gelu(h @ w_in + p[f"l{l}.b_mlp_in"])
        ff = ff @ p[f"l{l}.w_mlp_out"] + p[f"l{l}.b_mlp_out"]
        if dropout > 0.0:
            ff = _dropout(ff, dropout, jax.random.fold_in(base_key, 2 * l + 1))
        x = x + ff

    x = _layer_norm(x, p["lnf_scale"], p["lnf_bias"])
    return x @ p["embed"].T  # tied embedding head


# ---------------------------------------------------------------------------
# losses / training graphs (the AOT entry points)
# ---------------------------------------------------------------------------

def _masked_loss_sum(cfg, params_flat, tokens, mask, seed, *,
                     use_pallas=True, lora_flat=None):
    """Sum-reduced next-token loss with per-example mask (Prop. A.8)."""
    logits = forward(cfg, params_flat, tokens, seed,
                     dropout=cfg.dropout, use_pallas=use_pallas,
                     lora_flat=lora_flat)
    xent = ref.softmax_xent_ref(logits[:, :-1, :], tokens[:, 1:])  # [B,S-1]
    # PAD targets (token 0) carry no loss: the sum runs over *real*
    # tokens only.  Still reduction=sum — removing examples removes
    # addends (Prop. A.8); padding positions are exact zeros.
    pos = (tokens[:, 1:] != 0).astype(jnp.float32)
    per_ex = jnp.sum(xent * pos, axis=-1)                          # [B]
    loss = jnp.sum(per_ex * mask)
    count = jnp.sum(jnp.sum(pos, axis=-1) * mask)
    return loss, count


def train_step(cfg: ModelConfig, params_flat, tokens, mask, seed, *,
               use_pallas=True):
    """(grad f32[P], loss_sum, tok_count) for one microbatch.

    This is ``g(θ; B, S)`` of Eq. (4).  Accumulation across microbatches
    and the Update call live in the Rust coordinator so gradient order is
    explicit and logged.
    """
    def loss_fn(pf):
        loss, count = _masked_loss_sum(cfg, pf, tokens, mask, seed,
                                       use_pallas=use_pallas)
        return loss, count

    (loss, count), grad = jax.value_and_grad(loss_fn, has_aux=True)(params_flat)
    return grad, loss, count


def update_step(cfg: ModelConfig, params, grad, m, v, step, lr, *,
                use_pallas=True):
    """UPDATE of Eq. (4): global-norm clip (c=1.0) then fused AdamW."""
    return adamw_update(params, grad, m, v, step, lr,
                        beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
                        weight_decay=cfg.weight_decay,
                        clip_norm=cfg.clip_norm, use_pallas=use_pallas)


def eval_loss(cfg: ModelConfig, params_flat, tokens, *, use_pallas=True,
              lora_flat=None):
    """Per-example sum loss (f32[B]) + per-example token counts (f32[B]).

    Used by every audit: perplexity, MIA scores, canary exposure ranks.
    No dropout at eval.
    """
    logits = forward(cfg, params_flat, tokens, None, dropout=0.0,
                     use_pallas=use_pallas, lora_flat=lora_flat)
    xent = ref.softmax_xent_ref(logits[:, :-1, :], tokens[:, 1:])
    pos = (tokens[:, 1:] != 0).astype(jnp.float32)  # PAD carries no loss
    per_ex = jnp.sum(xent * pos, axis=-1)
    count = jnp.sum(pos, axis=-1)
    return per_ex, count


def next_logits(cfg: ModelConfig, params_flat, tokens, lens, *,
                use_pallas=True, lora_flat=None):
    """Logits at position lens[b]-1 for greedy decoding (extraction audit).

    tokens: i32[B,S] (padded), lens: i32[B].  Returns f32[B,V].
    """
    logits = forward(cfg, params_flat, tokens, None, dropout=0.0,
                     use_pallas=use_pallas, lora_flat=lora_flat)
    idx = jnp.clip(lens - 1, 0, cfg.seq_len - 1)
    return jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]


def lora_step(cfg: ModelConfig, base_flat, lora_flat, tokens, mask, seed, *,
              use_pallas=True):
    """Cohort-adapter microbatch step: grads w.r.t. the adapter ONLY.

    The base is strictly frozen (stop_gradient), satisfying the G2
    precondition: no base-weight or base-optimizer-state updates.
    """
    frozen = jax.lax.stop_gradient(base_flat)

    def loss_fn(lf):
        loss, count = _masked_loss_sum(cfg, frozen, tokens, mask, seed,
                                       use_pallas=use_pallas, lora_flat=lf)
        return loss, count

    (loss, count), grad = jax.value_and_grad(loss_fn, has_aux=True)(lora_flat)
    return grad, loss, count
