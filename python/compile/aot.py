"""AOT compile path: lower every L2 graph to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust coordinator then loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
calls Python again.

HLO TEXT is the interchange format, not serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Also exported:
  init_params.bin / init_lora.bin   deterministic f32-LE initializations
  manifest.json                     model config, flat-param layout,
                                    artifact IO signatures + SHA-256 pins
                                    (the Table 2 reproducibility pins)

Usage:  python -m compile.aot --out-dir ../artifacts [--preset tiny|small]
        [--d-model N --n-layers N --batch N --seq-len N --dropout R ...]
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .config import ModelConfig, TOKENIZER_SPEC, tiny, small


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(specs):
    return [{"dtype": str(s.dtype), "shape": list(s.shape)} for s in specs]


def sha256_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def build_entries(cfg: ModelConfig):
    """name -> (fn, [input ShapeDtypeStructs], [output names])."""
    P, PL = cfg.param_count, cfg.lora_param_count
    B, Be, S, V = cfg.batch, cfg.eval_batch, cfg.seq_len, cfg.vocab
    f32, i32 = jnp.float32, jnp.int32

    def sd(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    entries = {
        "train_step": (
            lambda p, t, m, s: model.train_step(cfg, p, t, m, s),
            [sd((P,)), sd((B, S), i32), sd((B,)), sd((), i32)],
            ["grad", "loss_sum", "tok_count"],
        ),
        "adamw_update": (
            lambda p, g, m, v, st, lr: model.update_step(cfg, p, g, m, v, st, lr),
            [sd((P,)), sd((P,)), sd((P,)), sd((P,)), sd((), i32), sd((), f32)],
            ["params", "m", "v"],
        ),
        "eval_loss": (
            lambda p, t: model.eval_loss(cfg, p, t),
            [sd((P,)), sd((Be, S), i32)],
            ["loss_sum", "tok_count"],
        ),
        "next_logits": (
            lambda p, t, l: model.next_logits(cfg, p, t, l),
            [sd((P,)), sd((Be, S), i32), sd((Be,), i32)],
            ["logits"],
        ),
        "lora_step": (
            lambda b, lo, t, m, s: model.lora_step(cfg, b, lo, t, m, s),
            [sd((P,)), sd((PL,)), sd((B, S), i32), sd((B,)), sd((), i32)],
            ["grad", "loss_sum", "tok_count"],
        ),
        "lora_adamw": (
            lambda p, g, m, v, st, lr: model.update_step(cfg, p, g, m, v, st, lr),
            [sd((PL,)), sd((PL,)), sd((PL,)), sd((PL,)), sd((), i32), sd((), f32)],
            ["lora", "m", "v"],
        ),
        "lora_eval": (
            lambda b, lo, t: model.eval_loss(cfg, b, t, lora_flat=lo),
            [sd((P,)), sd((PL,)), sd((Be, S), i32)],
            ["loss_sum", "tok_count"],
        ),
        "lora_next_logits": (
            lambda b, lo, t, l: model.next_logits(cfg, b, t, l, lora_flat=lo),
            [sd((P,)), sd((PL,)), sd((Be, S), i32), sd((Be,), i32)],
            ["logits"],
        ),
    }
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", choices=["tiny", "small"], default="tiny")
    ap.add_argument("--d-model", type=int)
    ap.add_argument("--n-heads", type=int)
    ap.add_argument("--n-layers", type=int)
    ap.add_argument("--d-ff", type=int)
    ap.add_argument("--seq-len", type=int)
    ap.add_argument("--batch", type=int)
    ap.add_argument("--eval-batch", type=int)
    ap.add_argument("--dropout", type=float)
    ap.add_argument("--lora-rank", type=int)
    ap.add_argument("--init-seed", type=int)
    args = ap.parse_args()

    cfg = tiny() if args.preset == "tiny" else small()
    for f in ("d_model", "n_heads", "n_layers", "d_ff", "seq_len", "batch",
              "eval_batch", "dropout", "lora_rank", "init_seed"):
        v = getattr(args, f)
        if v is not None:
            setattr(cfg, f, v)

    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    artifacts = {}
    for name, (fn, in_specs, out_names) in build_entries(cfg).items():
        lowered = jax.jit(fn, keep_unused=True).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out, fname)
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {
            "file": fname,
            "sha256": sha256_file(path),
            "inputs": _sig(in_specs),
            "outputs": out_names,
        }
        print(f"  lowered {name:18s} -> {fname} ({len(text)/1e6:.2f} MB)")

    # Deterministic initializations (the θ0 the trainer starts from).
    p0 = model.init_params(cfg)
    lora0 = model.init_lora(cfg)
    for fname, arr in (("init_params.bin", p0), ("init_lora.bin", lora0)):
        path = os.path.join(out, fname)
        import numpy as np
        with open(path, "wb") as f:
            f.write(np.asarray(arr, dtype=np.float32).tobytes())
        artifacts[fname] = {"file": fname, "sha256": sha256_file(path)}
        print(f"  wrote   {fname} ({arr.size * 4} B)")

    manifest = {
        "format_version": 1,
        "config": cfg.to_dict(),
        "tokenizer_checksum": hashlib.sha256(
            TOKENIZER_SPEC.encode()).hexdigest(),
        "jax_version": jax.__version__,
        "artifacts": artifacts,
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {out}/manifest.json (P={cfg.param_count}, "
          f"PL={cfg.lora_param_count})")


if __name__ == "__main__":
    main()
