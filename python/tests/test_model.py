"""L2 model-graph properties — the exactness preconditions of App. A.

The decisive ones for the paper's Theorem A.1:
  * mask content-independence (Lemma A.2(ii)): what sits in a masked slot
    cannot change any bit of the gradient;
  * reduction=sum additivity (Lemma A.3 / Prop. A.8): filtering removes
    addends, never rescales;
  * purity (Assumption A.13): same inputs -> bit-identical outputs.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import ModelConfig, tiny
from compile import model

CFG = tiny()
SETTINGS = dict(max_examples=10, deadline=None)


def mk_tokens(seed, b=None, s=None):
    r = np.random.default_rng(seed)
    return jnp.asarray(
        r.integers(1, CFG.vocab, (b or CFG.batch, s or CFG.seq_len)), jnp.int32
    )


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG)


def test_param_count_matches_layout(params):
    assert params.shape == (CFG.param_count,)
    total = sum(int(np.prod(s)) for _, s in CFG.layout())
    assert total == CFG.param_count


def test_unflatten_roundtrip(params):
    d = model.unflatten(CFG, params)
    flat = jnp.concatenate([d[n].reshape(-1) for n, _ in CFG.layout()])
    assert np.array_equal(np.asarray(flat), np.asarray(params))


def test_forward_shapes(params):
    toks = mk_tokens(0)
    logits = model.forward(CFG, params, toks)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_pure_function_bitwise(params):
    """Assumption A.13: g() is pure — two calls give identical bits."""
    toks, mask, seed = mk_tokens(1), jnp.ones(CFG.batch), jnp.int32(3)
    g1, l1, c1 = model.train_step(CFG, params, toks, mask, seed)
    g2, l2, c2 = model.train_step(CFG, params, toks, mask, seed)
    assert np.array_equal(np.asarray(g1), np.asarray(g2))
    assert float(l1) == float(l2)


def test_mask_content_independence_bitwise(params):
    """Lemma A.2(ii): junk in masked slots changes nothing, bit-for-bit.

    This is the property that lets ReplayFilter zero out forget-sample
    content during replay while remaining exact.
    """
    toks = mk_tokens(2)
    mask = jnp.array([1, 1, 1, 1, 0, 0, 1, 0], jnp.float32)
    g1, l1, _ = model.train_step(CFG, params, toks, mask, jnp.int32(9))
    junk = np.asarray(toks).copy()
    junk[4] = 255 - junk[4]
    junk[5] = 0
    junk[7] = np.random.default_rng(7).integers(0, 256, CFG.seq_len)
    g2, l2, _ = model.train_step(CFG, params, jnp.asarray(junk), mask,
                                 jnp.int32(9))
    assert float(l1) == float(l2)
    assert np.array_equal(np.asarray(g1), np.asarray(g2))


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_empty_mask_gives_zero_gradient(seed):
    """An all-filtered microbatch contributes exactly nothing (G=0)."""
    p = model.init_params(CFG)
    g, loss, count = model.train_step(CFG, p, mk_tokens(seed),
                                      jnp.zeros(CFG.batch), jnp.int32(0))
    assert float(loss) == 0.0
    assert float(count) == 0.0
    assert not np.any(np.asarray(g))


def test_sum_reduction_additivity(params):
    """Lemma A.3: microbatch gradient = sum of per-example gradients."""
    toks = mk_tokens(3)
    full, _, _ = model.train_step(CFG, params, toks, jnp.ones(CFG.batch),
                                  jnp.int32(0))
    acc = np.zeros(CFG.param_count, np.float32)
    for b in range(CFG.batch):
        m = np.zeros(CFG.batch, np.float32)
        m[b] = 1.0
        g, _, _ = model.train_step(CFG, params, toks, jnp.asarray(m),
                                   jnp.int32(0))
        acc += np.asarray(g)
    np.testing.assert_allclose(acc, np.asarray(full), rtol=1e-4, atol=1e-5)


def test_mean_reduction_would_break_equality(params):
    """Prop. A.8: with mean reduction, filtering rescales the gradient."""
    toks = mk_tokens(4)
    mask_all = jnp.ones(CFG.batch)
    mask_half = jnp.concatenate([jnp.ones(4), jnp.zeros(4)])
    g_all, l_all, c_all = model.train_step(CFG, params, toks, mask_all,
                                           jnp.int32(0))
    g_half, l_half, c_half = model.train_step(CFG, params, toks, mask_half,
                                              jnp.int32(0))
    # sum-reduction: the half gradient is NOT a rescaling of the full one —
    # it is the sum over the retained addends. Mean would have divided by
    # post-filter cardinality (c_half) and broken addend identity.
    mean_all = np.asarray(g_all) / float(c_all)
    mean_half = np.asarray(g_half) / float(c_half)
    assert not np.allclose(mean_all, mean_half, rtol=1e-3, atol=1e-5)


def test_update_step_deterministic_and_changes_params(params):
    g, _, _ = model.train_step(CFG, params, mk_tokens(5),
                               jnp.ones(CFG.batch), jnp.int32(0))
    m = jnp.zeros_like(params)
    v = jnp.zeros_like(params)
    a = model.update_step(CFG, params, g, m, v, jnp.int32(1), jnp.float32(1e-3))
    b = model.update_step(CFG, params, g, m, v, jnp.int32(1), jnp.float32(1e-3))
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert not np.array_equal(np.asarray(a[0]), np.asarray(params))


def test_eval_loss_consistent_with_train_loss(params):
    cfg = ModelConfig(eval_batch=CFG.batch)  # same B so shapes line up
    toks = mk_tokens(6)
    per_ex, counts = model.eval_loss(cfg, params, toks)
    _, train_loss, _ = model.train_step(cfg, params, toks,
                                        jnp.ones(cfg.batch), jnp.int32(0))
    np.testing.assert_allclose(float(jnp.sum(per_ex)), float(train_loss),
                               rtol=1e-5)
    # counts = number of non-PAD targets per example
    expected = np.sum(np.asarray(toks)[:, 1:] != 0, axis=-1)
    np.testing.assert_array_equal(np.asarray(counts), expected)


def test_next_logits_matches_forward(params):
    toks = mk_tokens(7, b=CFG.eval_batch)
    lens = jnp.asarray(
        np.random.default_rng(8).integers(1, CFG.seq_len + 1, CFG.eval_batch),
        jnp.int32)
    out = model.next_logits(CFG, params, toks, lens)
    full = model.forward(CFG, params, toks)
    for b in range(CFG.eval_batch):
        np.testing.assert_array_equal(np.asarray(out[b]),
                                      np.asarray(full[b, int(lens[b]) - 1]))


# ---------------------------------------------------------------------------
# dropout / seed semantics
# ---------------------------------------------------------------------------

def test_seed_ignored_when_dropout_zero(params):
    toks, mask = mk_tokens(9), jnp.ones(CFG.batch)
    g1, _, _ = model.train_step(CFG, params, toks, mask, jnp.int32(1))
    g2, _, _ = model.train_step(CFG, params, toks, mask, jnp.int32(999))
    assert np.array_equal(np.asarray(g1), np.asarray(g2))


def test_dropout_seed_sensitivity():
    cfg = ModelConfig(dropout=0.2)
    p = model.init_params(cfg)
    toks, mask = mk_tokens(10), jnp.ones(cfg.batch)
    g1, l1, _ = model.train_step(cfg, p, toks, mask, jnp.int32(1))
    g1b, l1b, _ = model.train_step(cfg, p, toks, mask, jnp.int32(1))
    g2, l2, _ = model.train_step(cfg, p, toks, mask, jnp.int32(2))
    # same seed -> bit identical; different seed -> different draws
    assert np.array_equal(np.asarray(g1), np.asarray(g1b))
    assert float(l1) != float(l2)


def test_dropout_mask_content_independence():
    """Index-stability holds with stochastic layers too (Lemma A.2)."""
    cfg = ModelConfig(dropout=0.2)
    p = model.init_params(cfg)
    toks = mk_tokens(11)
    mask = jnp.array([1, 0, 1, 0, 1, 0, 1, 0], jnp.float32)
    junk = np.asarray(toks).copy()
    junk[1::2] = 77
    g1, _, _ = model.train_step(cfg, p, toks, mask, jnp.int32(5))
    g2, _, _ = model.train_step(cfg, p, jnp.asarray(junk), mask, jnp.int32(5))
    assert np.array_equal(np.asarray(g1), np.asarray(g2))


# ---------------------------------------------------------------------------
# LoRA (G2 preconditions)
# ---------------------------------------------------------------------------

def test_lora_zero_patch_is_identity(params):
    """B=0 at init -> adapter-applied forward == base forward, bitwise...
    (up to XLA fusion differences; we require allclose and check the
    patch truly starts at zero)."""
    lora = model.init_lora(CFG)
    d = model.unflatten_lora(CFG, lora)
    for name, arr in d.items():
        if name.split(".")[-1].startswith("B"):
            assert not np.any(np.asarray(arr))
    toks = mk_tokens(12)
    base = model.forward(CFG, params, toks)
    patched = model.forward(CFG, params, toks, lora_flat=lora)
    np.testing.assert_allclose(base, patched, rtol=1e-6, atol=1e-6)


def test_lora_step_grads_only_adapter(params):
    lora = model.init_lora(CFG) + 0.01  # make B nonzero so grads flow
    toks, mask = mk_tokens(13), jnp.ones(CFG.batch)
    g, loss, _ = model.lora_step(CFG, params, lora, toks, mask, jnp.int32(0))
    assert g.shape == (CFG.lora_param_count,)
    assert float(jnp.max(jnp.abs(g))) > 0.0
    assert float(loss) > 0.0


def test_lora_step_mask_content_independence(params):
    lora = model.init_lora(CFG) + 0.01
    toks = mk_tokens(14)
    mask = jnp.array([1, 1, 0, 0, 1, 1, 0, 0], jnp.float32)
    junk = np.asarray(toks).copy()
    junk[2:4] = 9
    g1, _, _ = model.lora_step(CFG, params, lora, toks, mask, jnp.int32(0))
    g2, _, _ = model.lora_step(CFG, params, lora, jnp.asarray(junk), mask,
                               jnp.int32(0))
    assert np.array_equal(np.asarray(g1), np.asarray(g2))


def test_lora_eval_reflects_patch(params):
    lora = model.init_lora(CFG)
    toks = mk_tokens(15, b=CFG.eval_batch)
    base, _ = model.eval_loss(CFG, params, toks)
    with_zero, _ = model.eval_loss(CFG, params, toks, lora_flat=lora)
    np.testing.assert_allclose(base, with_zero, rtol=1e-5)
    r = np.random.default_rng(42)
    big = jnp.asarray(r.standard_normal(CFG.lora_param_count) * 0.2,
                      jnp.float32)
    with_big, _ = model.eval_loss(CFG, params, toks, lora_flat=big)
    assert not np.allclose(np.asarray(base), np.asarray(with_big), rtol=1e-3)
