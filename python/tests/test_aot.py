"""AOT path: lowering produces valid HLO text + manifest consistency."""

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig, tiny, TOKENIZER_SPEC
from compile import aot, model


@pytest.fixture(scope="module")
def cfg():
    # smaller-than-default so lowering every entry stays fast
    return ModelConfig(d_model=32, n_heads=2, n_layers=1, d_ff=64,
                       seq_len=32, batch=4, eval_batch=4, lora_rank=2)


def test_entries_cover_all_runtime_graphs(cfg):
    names = set(aot.build_entries(cfg))
    assert names == {
        "train_step", "adamw_update", "eval_loss", "next_logits",
        "lora_step", "lora_adamw", "lora_eval", "lora_next_logits",
    }


def test_every_entry_lowers_to_hlo_text(cfg):
    for name, (fn, in_specs, out_names) in aot.build_entries(cfg).items():
        lowered = jax.jit(fn).lower(*in_specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # return_tuple=True -> root is a tuple with len(out_names) elements
        assert len(text) > 1000, name


def test_lowered_hlo_is_deterministic(cfg):
    (fn, in_specs, _) = aot.build_entries(cfg)["adamw_update"]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*in_specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*in_specs))
    assert t1 == t2


def test_init_params_reproducible(cfg):
    a = np.asarray(model.init_params(cfg))
    b = np.asarray(model.init_params(cfg))
    assert np.array_equal(a, b)
    c = np.asarray(model.init_params(
        ModelConfig(**{**cfg.__dict__, "init_seed": cfg.init_seed + 1})))
    assert not np.array_equal(a, c)


def test_manifest_layout_matches_unflatten(cfg):
    d = cfg.to_dict()
    assert d["param_count"] == cfg.param_count
    flat = model.init_params(cfg)
    un = model.unflatten(cfg, flat)
    for ent in d["layout"]:
        n, shape, off = ent["name"], tuple(ent["shape"]), ent["offset"]
        size = int(np.prod(shape))
        np.testing.assert_array_equal(
            np.asarray(un[n]).reshape(-1),
            np.asarray(flat[off:off + size]))


def test_manifest_written_end_to_end(tmp_path, cfg, monkeypatch):
    import sys
    monkeypatch.setattr(sys, "argv", [
        "aot", "--out-dir", str(tmp_path), "--d-model", "32", "--n-heads",
        "2", "--n-layers", "1", "--d-ff", "64", "--seq-len", "32",
        "--batch", "4", "--eval-batch", "4", "--lora-rank", "2",
    ])
    aot.main()
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["config"]["param_count"] == cfg.param_count
    for name, meta in man["artifacts"].items():
        path = tmp_path / meta["file"]
        assert path.exists(), name
        assert aot.sha256_file(str(path)) == meta["sha256"]
    # init params binary round-trips to the exact jax initialization
    raw = np.fromfile(tmp_path / "init_params.bin", dtype=np.float32)
    assert np.array_equal(raw, np.asarray(model.init_params(cfg)))
    assert man["tokenizer_checksum"] == __import__("hashlib").sha256(
        TOKENIZER_SPEC.encode()).hexdigest()
