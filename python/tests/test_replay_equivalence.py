"""Mini G1 at the L2 level: ReplayFilter == preserved-graph oracle, bitwise.

A pure-python miniature of the full Rust workflow (the Rust integration
test `tests/replay_equality.rs` does the same through the AOT artifacts):
train a tiny model for a few logical steps with gradient accumulation,
"log" the WAL in memory, then check that

  oracle   = train from θ0 with forget examples masked from the start
  replay   = train from θ0 normally to checkpoint k (no forget influence
             before k by construction), then replay the tail filtering
             the forget closure

produce bit-identical (θ, m, v) — Theorem A.1 at toy scale.  Also checks
the empty-step-skip proposition and the Table-4 negative control
(checkpoint post-dating forget influence -> NOT bit-identical).
"""

import numpy as np
import jax.numpy as jnp

from compile.config import ModelConfig
from compile import model

CFG = ModelConfig(d_model=32, n_heads=2, n_layers=1, d_ff=64, seq_len=16,
                  batch=4)
STEPS = 6            # logical optimizer steps
ACCUM = 2            # microbatches per step
B, S = CFG.batch, CFG.seq_len


def make_schedule(seed=0):
    """[(tokens[B,S], base_mask[B], seed, lr)] per microbatch, in order."""
    r = np.random.default_rng(seed)
    sched = []
    for t in range(STEPS):
        for i in range(ACCUM):
            toks = r.integers(1, CFG.vocab, (B, S)).astype(np.int32)
            lr = 1e-3 * (0.9 ** t)
            sched.append((toks, t, i, lr))
    return sched


def run(sched, forget, start_state=None, start_at=0, zero_content=False):
    """Run the preserved-graph program, masking ``forget`` (set of
    (step, mb, slot)).  Implements empty-step skip: applied-update counter
    advances only when the accumulated segment had any contribution."""
    if start_state is None:
        p = model.init_params(CFG)
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        applied = 0
    else:
        p, m, v, applied = start_state
    G = None
    had = False
    states = {}
    for (toks, t, i, lr) in sched:
        if t < start_at:
            continue
        mask = np.ones(B, np.float32)
        toks_in = toks.copy()
        for slot in range(B):
            if (t, i, slot) in forget:
                mask[slot] = 0.0
                if zero_content:
                    toks_in[slot] = 0
        g, loss, cnt = model.train_step(CFG, p, jnp.asarray(toks_in),
                                        jnp.asarray(mask), jnp.int32(t * 31 + i))
        if float(cnt) > 0:
            had = True
        G = g if G is None else G + g
        if i == ACCUM - 1:  # accumulation boundary
            if had:
                applied += 1
                p, m, v = model.update_step(CFG, p, G, m, v,
                                            jnp.int32(applied),
                                            jnp.float32(lr))
            G, had = None, False
            states[t] = (p, m, v, applied)
    return p, m, v, applied, states


def bits_equal(a, b):
    return np.array_equal(np.asarray(a), np.asarray(b))


def test_g1_bitwise_equality_controlled():
    """Forget samples appear only from step 3; checkpoint at step 2."""
    sched = make_schedule()
    forget = {(3, 0, 1), (4, 1, 2), (5, 0, 0)}
    # oracle: masked from the start
    po, mo, vo, ao, _ = run(sched, forget)
    # original full run, checkpoint at end of step 2
    pf, mf, vf, af, states = run(sched, set())
    ck = states[2]
    # replay the tail from the checkpoint, filtering
    pr, mr, vr, ar, _ = run(sched, forget, start_state=ck, start_at=3)
    assert bits_equal(po, pr), "params must be bit-identical (G1)"
    assert bits_equal(mo, mr) and bits_equal(vo, vr), "optimizer state too"
    assert ao == ar


def test_g1_holds_with_zeroed_forget_content():
    """Content-scrubbed replay (zero the forget slots) is still exact."""
    sched = make_schedule(1)
    forget = {(3, 1, 0), (5, 1, 3)}
    po, mo, vo, _, _ = run(sched, forget)
    _, _, _, _, states = run(sched, set())
    pr, mr, vr, _, _ = run(sched, forget, start_state=states[2], start_at=3,
                           zero_content=True)
    assert bits_equal(po, pr) and bits_equal(mo, mr) and bits_equal(vo, vr)


def test_empty_step_skip_proposition():
    """A fully-forgotten logical step must not advance optimizer counters."""
    sched = make_schedule(2)
    # forget ALL slots of step 3 (both microbatches)
    forget = {(3, i, s) for i in range(ACCUM) for s in range(B)}
    po, mo, vo, ao, _ = run(sched, forget)
    pf, _, _, af, states = run(sched, set())
    pr, mr, vr, ar, _ = run(sched, forget, start_state=states[2], start_at=3)
    assert ao == STEPS - 1, "one empty step skipped"
    assert ar == ao
    assert bits_equal(po, pr) and bits_equal(mo, mr) and bits_equal(vo, vr)


def test_table4_negative_control():
    """Checkpoint post-dating forget influence -> inexact (paper Table 4)."""
    sched = make_schedule(3)
    forget = {(1, 0, 0), (4, 0, 1)}  # influence BEFORE the step-2 checkpoint
    po, _, _, _, _ = run(sched, forget)
    _, _, _, _, states = run(sched, set())
    pr, _, _, _, _ = run(sched, forget, start_state=states[2], start_at=3)
    diff = float(jnp.max(jnp.abs(po - pr)))
    assert diff > 0.0, "precondition violated, must NOT be bit-identical"


def test_counter_advance_would_break_equality():
    """Anti-property: advancing counters on empty steps breaks G1 —
    demonstrates why the empty-step-skip rule is load-bearing."""
    sched = make_schedule(4)
    forget = {(3, i, s) for i in range(ACCUM) for s in range(B)}
    po, _, _, _, _ = run(sched, forget)
    _, _, _, _, states = run(sched, set())

    # replay that (incorrectly) advances `applied` on the empty step
    p, m, v, applied = states[2]
    G, had = None, False
    for (toks, t, i, lr) in sched:
        if t < 3:
            continue
        mask = np.ones(B, np.float32)
        for slot in range(B):
            if (t, i, slot) in forget:
                mask[slot] = 0.0
        g, _, cnt = model.train_step(CFG, p, jnp.asarray(toks),
                                     jnp.asarray(mask), jnp.int32(t * 31 + i))
        G = g if G is None else G + g
        if i == ACCUM - 1:
            applied += 1  # BUG on purpose: advances even when empty
            if float(jnp.max(jnp.abs(G))) > 0:
                p, m, v = model.update_step(CFG, p, G, m, v,
                                            jnp.int32(applied),
                                            jnp.float32(lr))
            G = None
    assert not bits_equal(po, p)
