"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes (and dtypes for attention inputs); every case
asserts allclose against ``kernels/ref.py``.  This is the CORE
correctness signal for the AOT artifacts: the same kernels are lowered
into train_step/adamw_update HLO.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import flash_attention, _attn_bwd_math
from compile.kernels.adamw import adamw_fused, adamw_update

SETTINGS = dict(max_examples=20, deadline=None)


def rng_arrays(seed, *shapes, dtype=np.float32, scale=1.0):
    r = np.random.default_rng(seed)
    return [jnp.asarray(r.standard_normal(s) * scale, dtype) for s in shapes]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    s_blocks=st.integers(1, 4),
    block=st.sampled_from([8, 16]),
    dh=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_matches_ref_shapes(b, h, s_blocks, block, dh, seed):
    s = s_blocks * block
    q, k, v = rng_arrays(seed, (b, h, s, dh), (b, h, s, dh), (b, h, s, dh))
    out = flash_attention(q, k, v, block, block, True)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


@settings(**SETTINGS)
@given(
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_attention_dtypes(dtype, seed):
    q, k, v = rng_arrays(seed, (2, 2, 32, 16), (2, 2, 32, 16), (2, 2, 32, 16))
    q, k, v = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    out = flash_attention(q, k, v, 16, 16, True)
    expect = ref.attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    )
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol)


def test_flash_attention_noncausal():
    q, k, v = rng_arrays(3, (1, 2, 32, 8), (1, 2, 32, 8), (1, 2, 32, 8))
    out = flash_attention(q, k, v, 16, 16, False)
    expect = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)


def test_flash_attention_mixed_block_sizes():
    q, k, v = rng_arrays(4, (1, 1, 64, 16), (1, 1, 64, 16), (1, 1, 64, 16))
    ref_out = ref.attention_ref(q, k, v)
    for bq, bk in [(8, 32), (32, 8), (16, 64), (64, 16)]:
        out = flash_attention(q, k, v, bq, bk, True)
        np.testing.assert_allclose(out, ref_out, rtol=2e-5, atol=2e-5,
                                   err_msg=f"bq={bq} bk={bk}")


def test_flash_attention_grad_matches_ref():
    q, k, v = rng_arrays(5, (2, 2, 32, 8), (2, 2, 32, 8), (2, 2, 32, 8))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, 16, 16, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_flash_attention_causality():
    """Output at position i must not depend on keys/values at j > i."""
    q, k, v = rng_arrays(6, (1, 1, 32, 8), (1, 1, 32, 8), (1, 1, 32, 8))
    out1 = flash_attention(q, k, v, 8, 8, True)
    k2 = k.at[:, :, 20:, :].set(99.0)
    v2 = v.at[:, :, 20:, :].set(-99.0)
    out2 = flash_attention(q, k2, v2, 8, 8, True)
    np.testing.assert_array_equal(np.asarray(out1[:, :, :20, :]),
                                  np.asarray(out2[:, :, :20, :]))


def test_attn_bwd_math_is_vjp_of_ref():
    q, k, v = rng_arrays(7, (1, 2, 16, 8), (1, 2, 16, 8), (1, 2, 16, 8))
    g = rng_arrays(8, (1, 2, 16, 8))[0]
    _, vjp = jax.vjp(lambda q, k, v: ref.attention_ref(q, k, v), q, k, v)
    expect = vjp(g)
    got = _attn_bwd_math(q, k, v, g, True)
    for a, b in zip(got, expect):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fused AdamW
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 20000),
    step=st.integers(1, 10000),
    lr=st.floats(1e-6, 1e-1),
    seed=st.integers(0, 2**31 - 1),
)
def test_adamw_fused_matches_ref_shapes(n, step, lr, seed):
    p, g, m = rng_arrays(seed, (n,), (n,), (n,))
    v = jnp.abs(rng_arrays(seed + 1, (n,))[0])
    scalars = jnp.array([lr, 0.9, 0.999, 1e-8, 0.01,
                         1 - 0.9 ** step, 1 - 0.999 ** step, 1.0], jnp.float32)
    po, mo, vo = adamw_fused(p, g, m, v, scalars)
    # expected values computed in the SAME f32 semantics the kernel uses
    # (the f64-exponentiated ref.adamw_ref diverges in bias correction at
    # large step counts; the artifact's training dtype is f32 throughout)
    f = np.float32
    pn, gn, mn, vn = (np.asarray(x, f) for x in (p, g, m, v))
    bc1, bc2 = f(1 - 0.9 ** step), f(1 - 0.999 ** step)
    me = f(0.9) * mn + (f(1.0) - f(0.9)) * gn
    ve = f(0.999) * vn + (f(1.0) - f(0.999)) * gn * gn
    pe = pn - f(lr) * (me / bc1 / (np.sqrt(ve / bc2) + f(1e-8)) + f(0.01) * pn)
    np.testing.assert_allclose(po, pe, rtol=3e-5, atol=5e-7)
    np.testing.assert_allclose(mo, me, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(vo, ve, rtol=1e-6, atol=1e-7)
    # and the f64 oracle agrees semantically (loose tol: bc precision)
    pr, mr, vr = ref.adamw_ref(p, g, m, v, float(step), lr, 0.9, 0.999,
                               1e-8, 0.01, 1.0)
    np.testing.assert_allclose(po, pr, rtol=1e-2, atol=1e-5)


def test_adamw_fused_tile_boundary_sizes():
    """Exact tile multiples, off-by-one, and tiny N all pad correctly."""
    for n in [1, 5, 4095, 4096, 4097, 8192, 12345]:
        p, g, m = rng_arrays(n, (n,), (n,), (n,))
        v = jnp.abs(rng_arrays(n + 1, (n,))[0])
        scalars = jnp.array([1e-3, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 1.0],
                            jnp.float32)
        po, mo, vo = adamw_fused(p, g, m, v, scalars)
        pe, me, ve = ref.adamw_ref(p, g, m, v, 1.0, 1e-3, 0.9, 0.999, 1e-8,
                                   0.01, 1.0)
        # step=1 -> bc1=0.1, bc2=0.001 matches scalars above
        np.testing.assert_allclose(po, pe, rtol=1e-5, atol=1e-6)
        assert po.shape == (n,)


def test_adamw_update_clipping():
    """Global-norm clip engages exactly when ||g|| > c."""
    n = 1000
    p = jnp.zeros(n)
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    g_small = jnp.full(n, 1e-4)  # norm ~0.003 < 1 -> unclipped
    g_big = jnp.full(n, 1.0)     # norm ~31.6 > 1  -> scaled to norm 1
    kw = dict(beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
              clip_norm=1.0)
    p1, m1, _ = adamw_update(p, g_small, m, v, jnp.int32(1), jnp.float32(0.1), **kw)
    p2, m2, _ = adamw_update(p, g_big, m, v, jnp.int32(1), jnp.float32(0.1), **kw)
    # after clipping, g_big becomes g_big/||g_big|| -> m = 0.1*g/10... check norms
    gnorm_small = float(jnp.linalg.norm(g_small))
    np.testing.assert_allclose(jnp.linalg.norm(m1) / (1 - 0.9), gnorm_small,
                               rtol=1e-5)
    np.testing.assert_allclose(jnp.linalg.norm(m2) / (1 - 0.9), 1.0, rtol=1e-5)


def test_adamw_update_pallas_vs_ref_path():
    n = 10000
    p, g, m = rng_arrays(11, (n,), (n,), (n,))
    v = jnp.abs(rng_arrays(12, (n,))[0])
    kw = dict(beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01,
              clip_norm=1.0)
    a = adamw_update(p, g, m, v, jnp.int32(7), jnp.float32(3e-4),
                     use_pallas=True, **kw)
    b = adamw_update(p, g, m, v, jnp.int32(7), jnp.float32(3e-4),
                     use_pallas=False, **kw)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-7)


def test_adamw_deterministic_bitwise():
    """Update is a pure function: same inputs -> bit-identical outputs."""
    n = 4097
    p, g, m = rng_arrays(13, (n,), (n,), (n,))
    v = jnp.abs(rng_arrays(14, (n,))[0])
    scalars = jnp.array([1e-3, 0.9, 0.999, 1e-8, 0.01, 0.1, 0.001, 0.5],
                        jnp.float32)
    a = adamw_fused(p, g, m, v, scalars)
    b = adamw_fused(p, g, m, v, scalars)
    for x, y in zip(a, b):
        assert np.array_equal(np.asarray(x), np.asarray(y))
