//! Controlled G1 demonstration (paper §6.2, Table 5): byte-identical
//! equality of model and optimizer state between ReplayFilter and an
//! oracle retrain, emitted as `equality_proof_v2.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example equality_proof
//! ```

use std::collections::HashSet;

use unlearn::checkpoint::CheckpointStore;
use unlearn::config::RunConfig;
use unlearn::equality::{wal_segment_shas, EqualityProof};
use unlearn::harness;
use unlearn::replay::{load_run, offending_steps, replay_filter, ReplayOptions};
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&harness::artifacts_dir())?;
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let run_dir = std::path::PathBuf::from("runs/equality_proof");
    if run_dir.exists() {
        std::fs::remove_dir_all(&run_dir)?;
    }
    let cfg = RunConfig {
        run_dir: run_dir.clone(),
        steps: 16,
        accum: 2,
        checkpoint_every: 4,
        checkpoint_keep: 32,
        warmup: 4,
        ..Default::default()
    };

    println!("training {} steps with WAL + checkpoints ...", cfg.steps);
    Trainer::new(&rt, cfg.clone(), corpus.clone()).train(|_| false)?;
    let (records, idmap, pins) = load_run(&run_dir, None)?;
    let store = CheckpointStore::open(&run_dir.join("ckpt"), 64)?;

    // controlled setup: forget samples whose first WAL occurrence is
    // strictly after the checkpoint at step k (precondition of G1)
    let k = 8;
    let candidates = harness::ids_first_seen_at_or_after(&records, &idmap, k + 1);
    let closure: HashSet<u64> = candidates.into_iter().take(6).collect();
    println!(
        "forget closure: {:?} (first influence after checkpoint step {k})",
        {
            let mut v: Vec<_> = closure.iter().collect();
            v.sort();
            v
        }
    );
    let offending = offending_steps(&records, &idmap, &closure)?;
    anyhow::ensure!(
        offending.iter().all(|&t| t > k),
        "precondition violated — rerun with a later k"
    );

    let opts = ReplayOptions::default();
    println!("oracle: preserved-graph retain-only run from θ0 ...");
    let theta0 = store.load_full(0)?;
    let oracle = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &closure, Some(&pins), &opts,
    )?;
    println!("replay: filtered tail from checkpoint C_{k} ...");
    let ck = store.load_full(k)?;
    let replay = replay_filter(
        &rt, &corpus, &ck, &records, &idmap, &closure, Some(&pins), &opts,
    )?;

    let proof = EqualityProof::build(
        &oracle.state,
        &replay.state,
        oracle.invariants.clone(),
        replay.invariants.clone(),
        wal_segment_shas(&run_dir.join("wal"))?,
    );
    let path = run_dir.join("equality_proof_v2.json");
    proof.save(&path)?;
    println!("\n--- Table 5 ---");
    print!("{}", proof.render_table5());
    println!("proof JSON: {}", path.display());
    anyhow::ensure!(proof.status_pass, "G1 must hold");
    println!("G1 PASS ✓");
    Ok(())
}
