//! END-TO-END driver (DESIGN.md: the full-system validation example).
//!
//! Reproduces the paper's §6 workflow on the toy corpus (~2k samples,
//! canaried forget users, near-duplicates):
//!
//!   phase 1  deterministic training (few hundred steps), loss curve
//!   phase 2  Table 4 mechanics check — replay from a checkpoint that
//!            post-dates forget influence → NOT bit-identical
//!   phase 3  Table 5 controlled run — checkpoint precedes all forget
//!            influence → bit-identical model + optimizer (G1), equality
//!            proof JSON emitted
//!   phase 4  Table 6 audits — baseline vs ReplayFilter vs oracle
//!   phase 5  Table 7/8 overheads — WAL bytes, delta-ring budget
//!
//! Results land in runs/e2e/ (equality_proof_v2.json, audits.json,
//! losses.csv) and are summarized in EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_unlearning [--steps N]
//! ```

use std::collections::HashSet;

use unlearn::audit::{run_audits, ModelView};
use unlearn::checkpoint::CheckpointStore;
use unlearn::config::RunConfig;
use unlearn::equality::{wal_segment_shas, EqualityProof};
use unlearn::harness;
use unlearn::replay::{load_run, offending_steps, replay_filter, ReplayOptions};
use unlearn::runtime::Runtime;
use unlearn::trainer::Trainer;
use unlearn::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.get_u64("steps", 200)? as u32;
    let ckpt_every = args.get_u64("checkpoint-every", 25)? as u32;
    let run_dir = std::path::PathBuf::from(args.get_or("run-dir", "runs/e2e"));

    let rt = Runtime::load(&harness::artifacts_dir())?;
    let corpus = harness::toy_corpus(rt.manifest.seq_len);
    let n_forget_users = 5u32;
    let forget_request: Vec<u64> = (0..n_forget_users)
        .flat_map(|u| corpus.user_samples(u))
        .collect();
    println!(
        "== corpus: {} samples total; forget request covers {} samples \
         across users 0-{} (paper toy: 2009 total / 45 forget)",
        corpus.len(),
        forget_request.len(),
        n_forget_users - 1
    );

    // ---------------- phase 1: deterministic training ----------------
    if run_dir.exists() {
        std::fs::remove_dir_all(&run_dir)?;
    }
    let cfg = RunConfig {
        run_dir: run_dir.clone(),
        steps,
        accum: 2,
        checkpoint_every: ckpt_every,
        checkpoint_keep: 64,
        ring_window: 16,
        warmup: steps / 10,
        ..Default::default()
    };
    println!("== phase 1: training {steps} steps x{} microbatches ...", cfg.accum);
    let t0 = std::time::Instant::now();
    let full = Trainer::new(&rt, cfg.clone(), corpus.clone()).train(|_| false)?;
    println!(
        "   trained in {:.1}s; loss/token: first {:.3} -> last {:.3}",
        t0.elapsed().as_secs_f64(),
        full.losses.first().map(|x| x.1).unwrap_or(f32::NAN),
        full.losses.last().map(|x| x.1).unwrap_or(f32::NAN),
    );
    println!("   loss curve written to {}/losses.csv", run_dir.display());

    let (records, idmap, pins) = load_run(&run_dir, cfg.hmac_key.clone())?;
    let store = CheckpointStore::open(&run_dir.join("ckpt"), 64)?;
    let ndindex = unlearn::neardup::closure::build_index(&corpus);
    let closure_res = unlearn::neardup::expand_closure(
        &corpus,
        &ndindex,
        &forget_request,
        unlearn::neardup::ClosureParams::default(),
    );
    let closure: HashSet<u64> = closure_res.id_set();
    println!(
        "   forget closure: {} ids ({} added by near-dup expansion)",
        closure.len(),
        closure_res.expanded.len()
    );
    let offending = offending_steps(&records, &idmap, &closure)?;
    println!(
        "   offending steps: {} (first {}, last {})",
        offending.len(),
        offending.first().unwrap(),
        offending.last().unwrap()
    );

    let opts = ReplayOptions::default();
    let theta0 = store.load_full(0)?;
    println!("== oracle: preserved-graph retain-only run from θ0 ...");
    let oracle = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &closure, Some(&pins), &opts,
    )?;

    // -------- phase 2: Table 4 mechanics check (precondition violated) --
    let mid = store
        .nearest_at_or_before(steps / 2)?
        .expect("mid checkpoint");
    println!(
        "== phase 2 (Table 4): replay from step-{mid} checkpoint, which \
         POST-dates forget influence (first offending step {})",
        offending.first().unwrap()
    );
    let ck_mid = store.load_full(mid)?;
    let replay_bad = replay_filter(
        &rt, &corpus, &ck_mid, &records, &idmap, &closure, Some(&pins), &opts,
    )?;
    let bad = EqualityProof::build(
        &oracle.state,
        &replay_bad.state,
        oracle.invariants.clone(),
        replay_bad.invariants.clone(),
        vec![],
    );
    println!(
        "   Table 4 | max abs diff = {:.4e} | bit-identical? {}",
        bad.max_abs_diff,
        if bad.status_pass { "Yes" } else { "No (expected)" }
    );

    // -------- phase 3: Table 5 controlled run (precondition holds) -----
    println!("== phase 3 (Table 5): replay from θ0 checkpoint (precedes all \
              forget influence)");
    let replay_good = replay_filter(
        &rt, &corpus, &theta0, &records, &idmap, &closure, Some(&pins), &opts,
    )?;
    let proof = EqualityProof::build(
        &oracle.state,
        &replay_good.state,
        oracle.invariants.clone(),
        replay_good.invariants.clone(),
        wal_segment_shas(&run_dir.join("wal"))?,
    );
    proof.save(&run_dir.join("equality_proof_v2.json"))?;
    print!("{}", proof.render_table5());
    anyhow::ensure!(proof.status_pass, "G1 must hold in the controlled run");

    // -------- phase 4: Table 6 audits -----------------------------------
    println!("== phase 4 (Table 6): leakage + utility audits");
    let (retain_ids, eval_ids) =
        harness::audit_splits(&corpus, &closure, 0xE2E);
    let forget_vec: Vec<u64> = {
        let mut v: Vec<u64> = closure.iter().copied().collect();
        v.sort_unstable();
        v
    };
    let ctx = unlearn::audit::AuditContext {
        rt: &rt,
        corpus: &corpus,
        forget_ids: &forget_vec,
        retain_ids: &retain_ids,
        eval_ids: &eval_ids,
        baseline_ppl: None,
        thresholds: Default::default(),
        seed: 0xE2E,
    };
    let mut table6 = unlearn::util::json::Json::obj();
    let mut row = |name: &str, params: &[f32]| -> anyhow::Result<()> {
        let rep = run_audits(&ctx, ModelView::Base(params))?;
        println!(
            "   {:16} | PPL {:9.2} | MIA {:.3} (CI {:.3}-{:.3}) | canary μ \
             {:+.3}±{:.3} bits | extract {:.1}% | fuzzy {:.1}%",
            name,
            rep.retain_ppl,
            rep.mia_auc,
            rep.mia_ci.0,
            rep.mia_ci.1,
            rep.canary_mu_bits,
            rep.canary_sigma_bits,
            rep.extraction_rate * 100.0,
            rep.fuzzy_recall * 100.0
        );
        table6.set(name, rep.to_json());
        Ok(())
    };
    row("baseline-init", &theta0.params)?;
    row("full-model", &full.state.params)?;
    row("replay-filter", &replay_good.state.params)?;
    row("oracle-retrain", &oracle.state.params)?;
    std::fs::write(run_dir.join("audits.json"), table6.pretty())?;

    // -------- phase 5: Tables 7/8 overheads -----------------------------
    println!("== phase 5 (Tables 7/8): overheads");
    let n_records = records.len();
    println!(
        "   Table 7 | WAL: 32 B/record x {n_records} records = {} bytes",
        32 * n_records
    );
    let budget = full.ring.budget();
    println!(
        "   Table 8 | ring: {} B/step raw, window {}, pre-compress {} B, \
         stored {} B, ratio {:.2}",
        budget.per_step_bytes_raw,
        budget.window,
        budget.pre_compress_total,
        budget.stored_bytes,
        budget.compress_ratio
    );
    println!("== e2e complete; artifacts in {}", run_dir.display());
    Ok(())
}
