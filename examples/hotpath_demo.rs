//! Urgent hot path (paper §4.2(iii), Alg. A.4): curvature-guided
//! anti-update + short retain-tune, audit-gated with escalation to
//! exact replay on failure.
//!
//! ```bash
//! make artifacts && cargo run --release --example hotpath_demo
//! ```

use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::manifest::ActionKind;
use unlearn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&harness::artifacts_dir())?;
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = unlearn::config::RunConfig {
        run_dir: std::path::PathBuf::from("runs/hotpath"),
        steps: 16,
        accum: 2,
        checkpoint_every: 4,
        ring_window: 2, // tiny ring so the revert path CANNOT serve this
        warmup: 4,
        ..Default::default()
    };
    println!("training + estimating diagonal Fisher cache ...");
    let trained = harness::build_system(&rt, cfg, corpus, true)?;
    let mut system = trained.system;
    println!(
        "fisher cache over {} gradient samples",
        system.fisher.as_ref().map(|f| f.samples()).unwrap_or(0)
    );

    // an URGENT request for a canaried user whose data influenced
    // training early (outside the ring window)
    let req = ForgetRequest {
        id: "urgent-gdpr-17".into(),
        user: Some(0),
        sample_ids: vec![],
        urgency: Urgency::High,
    };
    println!("handling URGENT forget request for user 0 ...");
    let before_hash = system.state.model_hash();
    let outcome = system.handle(&req)?;
    println!(
        "action taken: {} (escalations: {:?})",
        outcome.action.as_str(),
        outcome.escalations
    );
    println!("details: {}", outcome.details.pretty());
    if let Some(a) = &outcome.audit {
        println!(
            "audits: MIA {:.3}, exposure μ {:+.2} bits, extraction {:.0}%, \
             pass={}",
            a.mia_auc,
            a.canary_mu_bits,
            a.extraction_rate * 100.0,
            a.pass()
        );
    }
    match outcome.action {
        ActionKind::HotPathAntiUpdate => {
            println!("hot path served the request (audits passed) ✓")
        }
        ActionKind::ExactReplay => {
            println!("hot path audits failed → escalated to exact replay ✓ \
                      (the paper's fail-safe)")
        }
        other => println!("served via {:?}", other.as_str()),
    }
    assert_ne!(before_hash, system.state.model_hash(), "model must change");
    println!(
        "manifest chain valid: {}",
        system
            .manifest
            .verify_chain()?
            .iter()
            .all(|(_, ok)| *ok)
    );
    Ok(())
}
