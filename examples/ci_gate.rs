//! Determinism & Replay CI gate (paper Alg. 5.1, Fig. 2) — run before
//! enabling forgetting in a deployment.
//!
//! ```bash
//! make artifacts && cargo run --release --example ci_gate
//! ```

use unlearn::config::RunConfig;
use unlearn::harness;
use unlearn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&harness::artifacts_dir())?;
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = RunConfig {
        run_dir: std::path::PathBuf::from("runs/cigate"),
        accum: 2,
        checkpoint_every: 4,
        warmup: 4,
        ..Default::default()
    };
    println!("running Algorithm 5.1 (train-train equality, ckpt-replay \
              equality, WAL scan) ...");
    let report = unlearn::cigate::run_gate(&rt, &cfg, &corpus, 10)?;
    for d in &report.details {
        println!("  {d}");
    }
    println!("{}", report.to_json().pretty());
    if report.pass() {
        println!("CI GATE PASS — forgetting may be enabled ✓");
        Ok(())
    } else {
        anyhow::bail!("CI GATE FAILED — forgetting blocked (fail-closed)");
    }
}
