//! Quickstart: train a tiny LM deterministically, file a forget request,
//! let the controller pick a path, and verify the signed manifest.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. load the AOT artifacts (compiled once by `make artifacts`)
    let rt = Runtime::load(&harness::artifacts_dir())?;
    println!(
        "loaded runtime: platform={} params={}",
        rt.platform(),
        rt.manifest.param_count
    );

    // 2. deterministic training with WAL + checkpoints + delta ring
    let cfg = unlearn::config::RunConfig {
        run_dir: std::path::PathBuf::from("runs/quickstart"),
        steps: 12,
        accum: 2,
        checkpoint_every: 4,
        ring_window: 8,
        warmup: 4,
        ..Default::default()
    };
    let corpus = harness::small_corpus(rt.manifest.seq_len);
    println!("training on {} samples ...", corpus.len());
    let trained = harness::build_system(&rt, cfg, corpus, false)?;
    let mut system = trained.system;
    println!(
        "trained: model={} applied_updates={}",
        system.state.model_hash(),
        system.state.applied_updates
    );

    // 3. file a forget request for user 0 (a canaried user)
    let req = ForgetRequest {
        id: "quickstart-req-1".into(),
        user: Some(0),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    };
    let outcome = system.handle(&req)?;
    println!(
        "controller action: {:?} (closure {} samples, {} from near-dup \
         expansion)",
        outcome.action.as_str(),
        outcome.closure_size,
        outcome.closure_expanded
    );
    if let Some(audit) = &outcome.audit {
        println!("audits: {}", audit.to_json().pretty());
    }

    // 4. the signed manifest now records the action; verify the chain
    let chain = system.manifest.verify_chain()?;
    println!(
        "forget manifest: {} entr{}, signatures valid: {}",
        chain.len(),
        if chain.len() == 1 { "y" } else { "ies" },
        chain.iter().all(|(_, ok)| *ok)
    );

    // 5. duplicate requests are idempotent
    let dup = system.handle(&req)?;
    assert!(!dup.executed, "duplicate suppressed by idempotency key");
    println!("duplicate request suppressed ✓");
    Ok(())
}
