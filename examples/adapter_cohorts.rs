//! Cohort-scoped adapter deletion (paper G2, Alg. A.5): data firewalled
//! into a LoRA adapter trained on a strictly frozen base is unlearned
//! *exactly* by deleting the adapter.
//!
//! ```bash
//! make artifacts && cargo run --release --example adapter_cohorts
//! ```

use unlearn::audit::ModelView;
use unlearn::controller::{ForgetRequest, Urgency};
use unlearn::harness;
use unlearn::manifest::ActionKind;
use unlearn::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(&harness::artifacts_dir())?;
    let mut corpus = harness::small_corpus(rt.manifest.seq_len);
    let cfg = unlearn::config::RunConfig {
        run_dir: std::path::PathBuf::from("runs/adapters"),
        steps: 10,
        accum: 2,
        checkpoint_every: 5,
        warmup: 2,
        ..Default::default()
    };

    // cohort 7 = users 20-21, fine-tuned in an adapter AFTER base training
    let cohort_users = [20u32, 21u32];
    corpus.tag_cohort(&cohort_users, 7);
    let cohort_ids: Vec<u64> = cohort_users
        .iter()
        .flat_map(|&u| corpus.user_samples(u))
        .collect();

    println!("training base (cohort data EXCLUDED — it is firewalled) ...");
    let cohort_set: std::collections::HashSet<u64> =
        cohort_ids.iter().copied().collect();
    let trained = {
        // base training filters the cohort out entirely
        let trainer =
            unlearn::trainer::Trainer::new(&rt, cfg.clone(), corpus.clone());
        let out = trainer.train_excluding(&cohort_set)?;
        harness::system_from_run(&rt, cfg, corpus.clone(), out, false)?
    };
    let mut system = trained.system;
    let base_hash = system.state.model_hash();

    println!("training cohort-7 adapter on the frozen base ...");
    let stats = system.adapters.train_cohort(
        &rt,
        &corpus,
        &system.state.params,
        7,
        &cohort_ids,
        12,
        5e-3,
        0xC0,
    )?;
    println!(
        "adapter trained: {} steps, final loss/token {:.3}",
        stats.steps, stats.final_loss_per_token
    );

    // sanity: the adapter actually changes the served model's behaviour
    let adapter = system.adapters.get(7).unwrap().params.clone();
    let probe: Vec<u64> = cohort_ids.iter().take(8).copied().collect();
    let base_losses = unlearn::audit::per_example_losses(
        &rt, ModelView::Base(&system.state.params), &corpus, &probe)?;
    let lora_losses = unlearn::audit::per_example_losses(
        &rt,
        ModelView::Adapter { base: &system.state.params, lora: &adapter },
        &corpus, &probe)?;
    let dbase: f32 = base_losses.iter().sum();
    let dlora: f32 = lora_losses.iter().sum();
    println!(
        "cohort loss under base {dbase:.1} vs base+adapter {dlora:.1} \
         (adapter specialized ✓)"
    );
    assert!(dlora < dbase, "adapter must fit its cohort");

    println!("forget request for cohort user 20 ...");
    let outcome = system.handle(&ForgetRequest {
        id: "cohort-forget-1".into(),
        user: Some(20),
        sample_ids: vec![],
        urgency: Urgency::Normal,
    })?;
    println!("controller action: {}", outcome.action.as_str());
    anyhow::ensure!(
        outcome.action == ActionKind::AdapterDelete,
        "cohort-confined data must route to adapter deletion"
    );
    anyhow::ensure!(
        system.adapters.get(7).is_none(),
        "adapter must be gone"
    );
    // G2: the base was never touched by cohort training or deletion
    assert_eq!(system.state.model_hash(), base_hash);
    println!("base untouched (hash {}), cohort influence removed exactly ✓",
             base_hash);

    // the merged-adapter refusal (Alg. A.5 line 1)
    system.adapters.train_cohort(
        &rt, &corpus, &system.state.params, 8,
        &corpus.user_samples(21), 4, 5e-3, 0xC1,
    )?;
    system.adapters.mark_merged(8);
    let err = system.adapters.delete_cohort(8);
    println!(
        "deleting a MERGED adapter refuses (escalate to replay): {}",
        err.err().map(|e| e.to_string()).unwrap_or_default()
    );
    Ok(())
}
